"""Elastic end-to-end: train -> node loss -> remesh -> restore -> continue.

Runs in a subprocess with 8 virtual devices (this process keeps 1).
Exercises the full production chain: sharded training state on a (4, 2)
mesh, async checkpoint, failure-detector verdict, elastic plan (drop a
data row), remesh over survivors, restore with RESHARDED placements, and
two more healthy steps with a rescaled batch.
"""

import subprocess
import sys
import textwrap


def test_elastic_restart_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np

        from repro.configs import get_smoke_config
        from repro.checkpoint import CheckpointManager
        from repro.data import DataConfig, SyntheticLM
        from repro.launch.mesh import make_mesh_from_devices
        from repro.optim import AdamWConfig
        from repro.runtime import FailureDetector, plan_elastic_mesh
        from repro.train import TrainConfig, build_train_step, \\
            init_train_state
        from repro.train.step import state_specs

        cfg = get_smoke_config("qwen3-1.7b")
        tcfg = TrainConfig(remat=False,
                           opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                           total_steps=10))
        devices = jax.devices()

        def named(mesh, specs):
            return jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))

        # ---- phase 1: healthy 4x2 mesh, batch 8 --------------------------
        mesh = make_mesh_from_devices(devices, data=4, model=2)
        step_fn, ctx, _ = build_train_step(cfg, mesh, tcfg, global_batch=8)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        sspecs = state_specs(mesh, jax.eval_shape(lambda: state), tcfg)
        state = jax.device_put(state, named(mesh, sspecs))
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=8, seq_len=64))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        with mesh:
            for s in range(3):
                b = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
                state, m = jit_step(state, b)
        loss_before = float(m["loss"])
        mgr = CheckpointManager("/tmp/elastic_ck", keep=2)
        mgr.save(state, 2)
        mgr.wait()

        # ---- phase 2: a data row dies -----------------------------------
        fd = FailureDetector(["h0", "h1", "h2", "h3"], suspect_after=1,
                             dead_after=2)
        fd.last_beat["h1"] -= 100        # h1 went silent
        alive, suspect, dead = fd.sweep()
        assert dead == ["h1"], dead
        plan = plan_elastic_mesh(4, 2, dead_hosts=["h1"],
                                 host_of_device=lambda d, m: f"h{d}")
        assert plan.new_data_size == 3 and plan.lost_rows == [1]

        # ---- phase 3: remesh over survivors, restore, continue ----------
        surv = [d for i, d in enumerate(devices[:8])
                if i // 2 != 1][: 3 * 2]
        mesh2 = make_mesh_from_devices(surv, data=3, model=2)
        # divisibility-guarded policy keeps specs valid on the 3-row mesh
        sspecs2 = state_specs(mesh2, jax.eval_shape(lambda: state), tcfg)
        state2, step = mgr.restore(jax.eval_shape(lambda: state),
                                   shardings=named(mesh2, sspecs2))
        assert step == 2
        new_batch = int(8 * plan.batch_scale * 2) // 2  # keep divisible
        step_fn2, _, _ = build_train_step(cfg, mesh2, tcfg,
                                          global_batch=6)
        jit2 = jax.jit(step_fn2, donate_argnums=(0,))
        data2 = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=6,
                                       seq_len=64))
        with mesh2:
            for s in range(3, 5):
                b = {k: jnp.asarray(v)
                     for k, v in data2.batch_at(s).items()}
                state2, m2 = jit2(state2, b)
        loss_after = float(m2["loss"])
        assert np.isfinite(loss_after)
        assert abs(loss_after - loss_before) < 1.0, \\
            (loss_before, loss_after)
        print("ELASTIC_OK", loss_before, loss_after)
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=500)
    assert "ELASTIC_OK" in out.stdout, (out.stdout[-1000:],
                                        out.stderr[-3000:])
