"""Pipeline parallelism: numerics vs the unpipelined reference.

The multi-stage case needs >1 device, so it runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (tests in this
process must keep seeing one device, per the dry-run ground rules).
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import bubble_fraction, split_stages


def test_split_stages_shapes():
    p = {"w": jnp.zeros((8, 4, 4)), "b": jnp.zeros((8, 4))}
    s = split_stages(p, 4)
    assert s["w"].shape == (4, 2, 4, 4)
    assert s["b"].shape == (4, 2, 4)


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 12) - 3 / 15) < 1e-9


def test_single_stage_pipeline_matches_reference():
    mesh = jax.make_mesh((1,), ("pipe",))
    from repro.parallel.pipeline import pipeline_forward
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)   # 4 layers
    x = jnp.asarray(rng.normal(size=(6, 2, 8)), jnp.float32)   # 6 micro

    def stage_fn(params, x):
        def layer(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(layer, x, params["w"])
        return h

    y = pipeline_forward(stage_fn, split_stages({"w": w}, 1),
                         x, mesh=mesh, axis="pipe")
    # reference: run all layers sequentially per microbatch
    def ref_one(xm):
        h = xm
        for i in range(4):
            h = jnp.tanh(h @ w[i])
        return h
    ref = jnp.stack([ref_one(x[i]) for i in range(6)])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5)


def test_multi_stage_pipeline_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_forward, split_stages

        mesh = jax.make_mesh((4,), ("pipe",))
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(8, 16, 16)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.normal(size=(8, 4, 16)), jnp.float32)

        def stage_fn(params, x):
            def layer(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(layer, x, params["w"])
            return h

        y = pipeline_forward(stage_fn, split_stages({"w": w}, 4), x,
                             mesh=mesh, axis="pipe")
        def ref_one(xm):
            h = xm
            for i in range(8):
                h = jnp.tanh(h @ w[i])
            return h
        ref = jnp.stack([ref_one(x[i]) for i in range(8)])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in out.stdout, out.stderr[-2000:]
