"""The mesh-sharded rounds engine (core/rounds/sharded.py).

In-process tests run on a 1-shard mesh (shard_map machinery, bucket
routing, overflow deferral, trace counts, eviction — all real); the
multi-shard differential parity test runs in a subprocess with 4
virtual devices, replaying ONE concurrent mixed read/write/upgrade
trace through the single-shard engine and the 4-shard engine and
asserting identical per-line version histories in write-through AND
write-back modes (the PR's acceptance trace).
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounds as rp
from repro.core.rounds import engine

# Same determinism constraints as tests/test_parity_rounds.py: per batch
# a line has either concurrent readers or exactly one writer; upgrades
# (sole-S and contended) and steals happen ACROSS batches.
TRACE = [
    [(0, 0, 0), (1, 0, 0), (2, 1, 0), (3, 2, 0)],          # warm S copies
    [(0, 0, 1), (3, 3, 1), (2, 2, 1)],                     # upgrades+steals
    [(1, 0, 0), (2, 0, 0), (0, 4, 0), (2, 1, 1)],          # PeerRd + sole-S
    [(0, 0, 1), (1, 1, 1), (3, 5, 1)],                     # contended upgr
    [(1, 0, 0), (2, 2, 0), (0, 1, 0), (3, 4, 0)],          # re-read all
    [(2, 3, 1), (1, 5, 1), (0, 2, 1)],                     # steal round
    [(n, l, 0) for n, l in zip(range(4), (0, 1, 2, 3))]
    + [(0, 4, 0), (1, 5, 0)],                              # final audit
]
N_NODES, N_LINES = 4, 8


def _mesh1():
    return jax.make_mesh((1,), ("shards",))


def _ops_tc(state, node, line, isw, wdata=None, **kw):
    # legacy run_ops_to_completion call shape via the DevicePlane facade
    plane = rp.DevicePlane.open(state, kw.pop("mesh", None), **kw)
    res = plane.ops(node, line, isw, wdata)
    if wdata is not None:
        return plane.state, res.version, res.rounds, res.data
    return plane.state, res.version, res.rounds


def _batch_arrays(batch):
    return (np.asarray([b[0] for b in batch], np.int32),
            np.asarray([b[1] for b in batch], np.int32),
            np.asarray([b[2] for b in batch], np.int32))


def _replay(state, *, mesh=None, **kw):
    out = []
    for batch in TRACE:
        node, line, isw = _batch_arrays(batch)
        state, vers, _ = _ops_tc(
            state, node, line, isw, n_nodes=N_NODES, mesh=mesh, **kw)
        rp.check_invariants(state)
        out.append([int(v) for v in vers])
    return out, state


def _wdata(batch_idx, batch, width=2):
    """Deterministic write payloads: lane 0 = batch*16+slot+1, lane 1 =
    the writing node (zeros for reads)."""
    return np.asarray(
        [[batch_idx * 16 + slot + 1, node] if isw else [0] * width
         for slot, (node, _, isw) in enumerate(batch)], np.int32)


def _replay_bytes(state, *, mesh=None, **kw):
    out = []
    for b, batch in enumerate(TRACE):
        node, line, isw = _batch_arrays(batch)
        state, vers, _, data = _ops_tc(
            state, node, line, isw, _wdata(b, batch), n_nodes=N_NODES,
            mesh=mesh, **kw)
        rp.check_invariants(state)
        out.append([(int(v),) + tuple(int(x) for x in d)
                    for v, d in zip(vers, data)])
    return out, state


# ------------------------------------------------------ stripe layout

def test_stripe_state_roundtrip():
    state = rp.make_state(3, 12, write_back=True)
    state["mem_version"] = jnp.arange(12, dtype=jnp.int32)
    back = rp.unstripe_state(rp.stripe_state(state, 4), 4)
    for k in state:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(state[k]), err_msg=k)


def test_stripe_layout_is_home_major():
    # global line l lands on shard l % S at local index l // S
    state = rp.make_state(2, 8)
    state["mem_version"] = jnp.arange(8, dtype=jnp.int32)
    striped = rp.stripe_state(state, 4)
    np.testing.assert_array_equal(
        np.asarray(striped["mem_version"]),
        np.asarray([0, 4, 1, 5, 2, 6, 3, 7]))


# ------------------------------------------- single-shard differential

@pytest.mark.parametrize("write_back", [False, True])
def test_single_shard_mesh_matches_flat_engine(write_back):
    """The sharded engine on a 1-shard mesh IS the flat engine: same
    per-op version history AND bit-identical final state."""
    mesh = _mesh1()
    flat, flat_state = _replay(rp.make_state(N_NODES, N_LINES,
                                             write_back=write_back))
    shd, shd_state = _replay(
        rp.make_sharded_state(N_NODES, N_LINES, mesh,
                              write_back=write_back), mesh=mesh)
    assert flat == shd
    gathered = rp.unshard_state(shd_state, mesh)
    for k in flat_state:
        np.testing.assert_array_equal(np.asarray(flat_state[k]),
                                      np.asarray(gathered[k]), err_msg=k)


@pytest.mark.parametrize("write_back", [False, True])
def test_single_shard_mesh_matches_flat_engine_bytes(write_back):
    """Byte-content differential: the payload-plane trace through the
    flat and 1-shard engines — identical (version, bytes) per op and
    bit-identical payload leaves (mem_data/cache_data included)."""
    mesh = _mesh1()
    flat, flat_state = _replay_bytes(
        rp.make_state(N_NODES, N_LINES, write_back=write_back,
                      payload_width=2))
    shd, shd_state = _replay_bytes(
        rp.make_sharded_state(N_NODES, N_LINES, mesh,
                              write_back=write_back, payload_width=2),
        mesh=mesh)
    assert flat == shd
    gathered = rp.unshard_state(shd_state, mesh)
    assert set(gathered) == set(flat_state) >= {"mem_data", "cache_data"}
    for k in flat_state:
        np.testing.assert_array_equal(np.asarray(flat_state[k]),
                                      np.asarray(gathered[k]), err_msg=k)


# -------------------------------------------------- overflow deferral

def test_bucket_overflow_defers_and_completes():
    """More requests for one home than the bucket holds: the overflow
    defers and respins INSIDE the loop (the caller never sees it), and
    the version history is complete — pre-PR the distributed plane
    punted this to the caller, with zero tests."""
    mesh = _mesh1()
    state = rp.make_sharded_state(2, 4, mesh)
    node = np.asarray([0, 1, 0, 1, 0, 1], np.int32)
    line = np.full(6, 1, np.int32)
    isw = np.ones(6, np.int32)
    state, vers, rounds = _ops_tc(
        state, node, line, isw, n_nodes=2, mesh=mesh, bucket_cap=2,
        max_rounds=64)
    assert sorted(vers.tolist()) == [1, 2, 3, 4, 5, 6]
    assert rounds > 3          # it actually had to respin
    assert int(np.asarray(state["mem_version"])[1]) == 6
    rp.check_invariants(state)


def test_bucket_overflow_defers_and_carries_payloads():
    """The defer/respin path must carry BYTES too: a deferred write's
    payload re-presents with it and lands when its CAS finally wins,
    and each op's reply bytes match its group's serialized write."""
    mesh = _mesh1()
    state = rp.make_sharded_state(2, 4, mesh, payload_width=2)
    node = np.asarray([0, 1, 0, 1, 0, 1], np.int32)
    line = np.full(6, 1, np.int32)
    isw = np.ones(6, np.int32)
    wd = np.stack([10 * np.arange(1, 7), np.arange(1, 7)],
                  axis=1).astype(np.int32)
    state, vers, rounds, data = _ops_tc(
        state, node, line, isw, wd, n_nodes=2, mesh=mesh, bucket_cap=2,
        max_rounds=64)
    assert sorted(vers.tolist()) == [1, 2, 3, 4, 5, 6]
    assert rounds > 3
    rp.check_invariants(state)
    # with cap=2 and alternating nodes, each deferred write re-presents
    # alone and serializes as its own group: its reply bytes are its OWN
    # payload, and memory ends with the last-serialized write's bytes
    for i in range(6):
        assert data[i].tolist() == wd[i].tolist(), i
    last = int(np.argmax(vers))
    assert np.asarray(state["mem_data"])[1].tolist() == wd[last].tolist()


def test_overflow_unserved_slots_report_at_bound():
    mesh = _mesh1()
    state = rp.make_sharded_state(2, 4, mesh)
    node = np.asarray([0, 1], np.int32)
    line = np.asarray([1, 1], np.int32)
    with pytest.raises(RuntimeError, match="not served"):
        _ops_tc(state, node, line, np.ones(2, np.int32),
                                 n_nodes=2, mesh=mesh, bucket_cap=1,
                                 max_rounds=1)


# ------------------------------------------------- trace-count proof

def test_sharded_loop_compiles_once_per_shape():
    mesh = _mesh1()
    state = rp.make_sharded_state(4, 16, mesh)

    def batch(seed):
        r = np.random.default_rng(seed)
        return (r.integers(0, 4, 8).astype(np.int32),
                r.integers(0, 16, 8).astype(np.int32),
                r.integers(0, 2, 8).astype(np.int32))

    state, _, rounds1 = _ops_tc(
        state, *batch(1), n_nodes=4, mesh=mesh)
    key = ("sharded", 1, 4, 16, 8, 8, 64, "ref", False, 0,
           False, False)
    baseline = dict(engine.TRACE_COUNTS)
    assert baseline.get(key, 0) == 1, \
        "sharded driver must trace once per shape"
    total = rounds1
    for seed in range(2, 8):
        state, _, r = _ops_tc(
            state, *batch(seed), n_nodes=4, mesh=mesh)
        total += r
    assert total > 7, "sweep must actually spin multiple rounds"
    assert engine.TRACE_COUNTS[key] == 1
    rp.check_invariants(state)


# ----------------------------------------------------------- eviction

def test_sharded_eviction_write_back_parity():
    mesh = _mesh1()
    flat = rp.make_state(3, 4, write_back=True)
    shd = rp.make_sharded_state(3, 4, mesh, write_back=True)
    node = np.asarray([2], np.int32)
    line = np.asarray([0], np.int32)
    isw = np.ones(1, np.int32)
    flat, _, _ = _ops_tc(flat, node, line, isw,
                                          n_nodes=3)
    shd, _, _ = _ops_tc(shd, node, line, isw,
                                         n_nodes=3, mesh=mesh)
    flat = rp.evict_lines(flat, jnp.asarray(node), jnp.asarray(line))
    shd = rp.evict_lines_sharded(shd, node, line, mesh=mesh)
    gathered = rp.unshard_state(shd, mesh)
    for k in flat:
        np.testing.assert_array_equal(np.asarray(flat[k]),
                                      np.asarray(gathered[k]), err_msg=k)
    assert int(np.asarray(gathered["mem_version"])[0]) == 1  # flushed


# ------------------------------------------------------------- guards

def test_pad_ops_pads_to_shard_multiple():
    node, line, isw = rp.pad_ops([0], [1], [1], 4)
    assert line.shape[0] == 4 and (line[1:] == -1).all()
    assert node.shape == isw.shape == line.shape
    n2, l2, w2 = rp.pad_ops([0, 1], [1, 2], [1, 0], 2)
    assert l2.tolist() == [1, 2]             # already a multiple: no-op
    del n2, w2


# --------------------------------------- multi-shard (4 virtual devices)

def test_multi_shard_parity_subprocess():
    """THE acceptance test: one concurrent mixed read/write/upgrade
    trace through the single-shard engine and the 4-shard engine —
    identical per-line version histories, write-through AND write-back;
    plus hot-home overflow completion and the 4-shard trace-count
    proof."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, numpy as np
        from repro.core import rounds as rp
        from repro.core.rounds import engine
        from repro.apps.workloads import (DeviceRoundsConfig,
                                          device_rounds_batches)

        TRACE = {TRACE!r}
        N_NODES, N_LINES = {N_NODES}, {N_LINES}
        mesh = jax.make_mesh((4,), ("shards",))

        def _ops_tc(state, node, line, isw, wdata=None, **kw):
            plane = rp.DevicePlane.open(state, kw.pop("mesh", None), **kw)
            res = plane.ops(node, line, isw, wdata)
            if wdata is not None:
                return plane.state, res.version, res.rounds, res.data
            return plane.state, res.version, res.rounds

        def arrays(batch):
            return (np.asarray([b[0] for b in batch], np.int32),
                    np.asarray([b[1] for b in batch], np.int32),
                    np.asarray([b[2] for b in batch], np.int32))

        def wdata(b, batch):
            return np.asarray(
                [[b * 16 + s + 1, n] if w else [0, 0]
                 for s, (n, _, w) in enumerate(batch)], np.int32)

        for write_back in (False, True):
            # version-only plane AND payload plane: flat vs 4 shards
            flat = rp.make_state(N_NODES, N_LINES, write_back=write_back)
            shd = rp.make_sharded_state(N_NODES, N_LINES, mesh,
                                        write_back=write_back)
            flat_p = rp.make_state(N_NODES, N_LINES,
                                   write_back=write_back, payload_width=2)
            shd_p = rp.make_sharded_state(N_NODES, N_LINES, mesh,
                                          write_back=write_back,
                                          payload_width=2)
            for b, batch in enumerate(TRACE):
                node, line, isw = arrays(batch)
                flat, v1, _ = _ops_tc(
                    flat, node, line, isw, n_nodes=N_NODES)
                shd, v2, _ = _ops_tc(
                    shd, node, line, isw, n_nodes=N_NODES, mesh=mesh)
                assert v1.tolist() == v2.tolist(), (
                    write_back, batch, v1.tolist(), v2.tolist())
                rp.check_invariants(shd)
                wd = wdata(b, batch)
                flat_p, v3, _, d3 = _ops_tc(
                    flat_p, node, line, isw, wd, n_nodes=N_NODES)
                shd_p, v4, _, d4 = _ops_tc(
                    shd_p, node, line, isw, wd, n_nodes=N_NODES,
                    mesh=mesh)
                # byte-content differential: (version, bytes) agree
                # between the flat and 4-shard payload planes, and the
                # payload plane serializes exactly like the bare one
                assert v3.tolist() == v1.tolist()
                assert v4.tolist() == v1.tolist()
                assert d3.tolist() == d4.tolist(), (write_back, batch)
                rp.check_invariants(shd_p)
            g = rp.unshard_state(shd, mesh)
            for k in flat:
                np.testing.assert_array_equal(
                    np.asarray(flat[k]), np.asarray(g[k]), err_msg=k)
            gp = rp.unshard_state(shd_p, mesh)
            assert "mem_data" in gp and "cache_data" in gp
            for k in flat_p:
                np.testing.assert_array_equal(
                    np.asarray(flat_p[k]), np.asarray(gp[k]), err_msg=k)

        # hot home + tiny buckets: every source shard overflows toward
        # home 0, the loop defers and respins, history stays complete
        state = rp.make_sharded_state(4, 8, mesh)
        R = 16
        node = np.asarray([i % 4 for i in range(R)], np.int32)
        line = np.zeros(R, np.int32)
        isw = np.ones(R, np.int32)
        state, vers, rounds = _ops_tc(
            state, node, line, isw, n_nodes=4, mesh=mesh,
            bucket_cap=1, max_rounds=128)
        assert sorted(vers.tolist()) == list(range(1, R + 1))
        rp.check_invariants(state)

        # same hot-home overflow storm, payload-carrying: the deferred
        # slots respin WITH their bytes, and the final memory image is
        # the payload of whichever write serialized last
        state_p = rp.make_sharded_state(4, 8, mesh, payload_width=2)
        wd = np.stack([7 * np.arange(1, R + 1), np.arange(1, R + 1)],
                      axis=1).astype(np.int32)
        state_p, vers_p, _, data_p = _ops_tc(
            state_p, node, line, isw, wd, n_nodes=4, mesh=mesh,
            bucket_cap=1, max_rounds=256)
        assert sorted(vers_p.tolist()) == list(range(1, R + 1))
        rp.check_invariants(state_p)
        # the reply of the last-serialized slot carries its group's
        # final bytes — exactly what write-through left in memory
        md = rp.unshard_state(state_p, mesh)["mem_data"]
        last = int(np.argmax(vers_p))
        assert np.asarray(md)[0].tolist() == data_p[last].tolist()

        # trace-count proof at 4 shards: shapes repeat, no retrace
        key = ("sharded", 4, 4, 8, 16, 1, 128, "ref", False, 0,
               False, False)
        assert engine.TRACE_COUNTS.get(key, 0) == 1
        state2 = rp.make_sharded_state(4, 8, mesh)
        state2, _, _ = _ops_tc(
            state2, node, line, isw, n_nodes=4, mesh=mesh,
            bucket_cap=1, max_rounds=128)
        assert engine.TRACE_COUNTS[key] == 1

        # static-shape guards need a real multi-device mesh to fire
        try:
            rp.run_rounds_sharded(
                rp.make_sharded_state(2, 8, mesh),
                np.zeros(3, np.int32), np.zeros(3, np.int32),
                np.zeros(3, np.int32), mesh=mesh, n_nodes=2)
            raise SystemExit("indivisible R accepted")
        except ValueError as e:
            assert "not divisible" in str(e)
        try:
            rp.shard_state(rp.make_state(2, 6), mesh)
            raise SystemExit("indivisible n_lines accepted")
        except ValueError as e:
            assert "not divisible" in str(e)
        assert rp.make_sharded_state(2, 6, mesh)["words"].shape[0] == 8

        # workload soup: Zipf/YCSB generator batches, invariants hold
        cfg = DeviceRoundsConfig(n_nodes=4, n_lines=16, r_slots=12,
                                 read_ratio=0.5, zipf_theta=0.9,
                                 iters=4)
        soup = rp.make_sharded_state(4, 16, mesh, write_back=True)
        for node, line, isw in device_rounds_batches(cfg, seed=5):
            soup, _, _ = _ops_tc(
                soup, node, line, isw, n_nodes=4, mesh=mesh,
                max_rounds=128)
            rp.check_invariants(soup)

        # payload soup on 4 shards: random mixed ops with random bytes,
        # data/version agreement checked on every materialized state
        cfgp = DeviceRoundsConfig(n_nodes=4, n_lines=16, r_slots=12,
                                  read_ratio=0.5, zipf_theta=0.9,
                                  iters=4, payload_width=3)
        soup_p = rp.make_sharded_state(4, 16, mesh, write_back=True,
                                       payload_width=3)
        for node, line, isw, wd in device_rounds_batches(cfgp, seed=6):
            soup_p, _, _, _ = _ops_tc(
                soup_p, node, line, isw, wd, n_nodes=4, mesh=mesh,
                max_rounds=128)
            rp.check_invariants(soup_p)

        # mesh-backed SELCCKVPool on the rounds data plane: a mixed
        # append/read trace vs a host-replayed numpy oracle — reads
        # must return the exact bytes the serialized appends left
        from repro.dsm.kvpool import KVPoolConfig, SELCCKVPool
        kcfg = KVPoolConfig(n_pages=8, page_size=4, n_kv_heads=1,
                            head_dim=8, n_replicas=4, cache_slots=4,
                            dtype="float32")
        kpool = SELCCKVPool(kcfg, mesh=mesh)
        pages = kpool.allocate(8)
        kpool.open_rounds_plane()
        ok = np.zeros((8, 4, 1, 8), np.float32)
        ov = np.zeros((8, 4, 1, 8), np.float32)
        rng = np.random.default_rng(9)
        for t in range(10):
            rep = t % 4
            pg = np.asarray([pages[t % 8], pages[(t + 3) % 8]], np.int32)
            off = np.asarray([t % 4, (t + 1) % 4], np.int32)
            kn = rng.normal(size=(2, 1, 8)).astype(np.float32)
            vn = rng.normal(size=(2, 1, 8)).astype(np.float32)
            kpool.append(pg, off, kn, vn, replica=rep)
            for i in range(2):
                ok[pg[i], off[i]] = kn[i]
                ov[pg[i], off[i]] = vn[i]
            reader = (t + 1) % 4
            rd = np.asarray([pages[t % 8], pages[(t + 5) % 8]], np.int32)
            k, v, _ = kpool.read(reader, rd)
            np.testing.assert_array_equal(np.asarray(k), ok[rd])
            np.testing.assert_array_equal(np.asarray(v), ov[rd])
        # attention consumes the same plane bytes
        q = rng.normal(size=(1, 2, 8)).astype(np.float32)
        out = kpool.attend(q, np.asarray([[pages[0], pages[1]]],
                                         np.int32),
                           np.asarray([8], np.int32))
        assert np.isfinite(np.asarray(out)).all()
        print("SHARDED_PARITY_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_PARITY_OK" in out.stdout, out.stderr[-3000:]
