import os
import sys

# smoke tests and benches must see ONE cpu device (the dry-run sets its own
# 512-device flag in its own process) — keep XLA_FLAGS untouched here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
