"""Serving-engine tests: slot lifecycle edges, engine counters, and the
differential trace (continuous-batching engine vs the synchronous
gang-batch oracle) on the flat and 4-shard planes.

The differential contract is strict: identical per-request token
outputs (the token path is integer-only, so equality is exact), a
bit-exact KV readback of every completed request's written positions
through the coherence plane, and a leak-free pool — every slot-private
page back on the free list once serving drains.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dsm.kvpool import KVPoolConfig, SELCCKVPool
from repro.kernels.paged_attention.ops import decode_paged
from repro.serve import (QueueFull, RequestState, ServeLoop,
                         ServeRequest, SyncBatchServer, ToyLM,
                         write_pages)

CFG = KVPoolConfig(n_pages=24, page_size=4, n_kv_heads=2, head_dim=4,
                   n_replicas=2, dtype="float32")


def _pool(cfg=CFG, mesh=None):
    pool = SELCCKVPool(cfg, mesh=mesh)
    pool.open_rounds_plane()
    return pool


def _shared_prefix(pool, model, tokens):
    """Prefill a shared prefix into pool pages via coherent writes."""
    ps = pool.cfg.page_size
    assert len(tokens) % ps == 0
    pages = pool.allocate(len(tokens) // ps)
    shape = (len(pages), ps, model.n_kv_heads, model.head_dim)
    kp, vp = np.zeros(shape, np.float32), np.zeros(shape, np.float32)
    for i, t in enumerate(tokens):
        kp[i // ps, i % ps], vp[i // ps, i % ps] = model.kv(t, i)
    write_pages(pool, pages, kp, vp)
    return pages


def _mixed_trace(shared, n=9, seed=7):
    """[(prompt, max_new, shared_pages, shared_len)] — mixed prompt
    lengths, budgets, and shared-prefix usage."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = [int(x) for x in rng.integers(0, 97,
                                               int(rng.integers(1, 5)))]
        g = int(rng.integers(1, 6))
        if i % 3 == 0:
            out.append((prompt, g, tuple(shared), 4))
        else:
            out.append((prompt, g, (), 0))
    return out


# ------------------------------------------------------ lifecycle edges

def test_queue_full_raises():
    pool = _pool()
    loop = ServeLoop(pool, ToyLM(CFG), n_slots=1, max_pages=4,
                     queue_capacity=2)
    loop.submit([1], 2)
    loop.submit([2], 2)
    with pytest.raises(QueueFull):
        loop.submit([3], 2)
    assert loop.stats().queue_depth == 2


def test_oversize_request_rejected():
    pool = _pool()
    loop = ServeLoop(pool, ToyLM(CFG), n_slots=2, max_pages=2,
                     queue_capacity=4)
    # kv_len = 6 + 4 - 1 = 9 -> 3 pages > max_pages=2
    with pytest.raises(ValueError, match="slot capacity"):
        loop.submit([1, 2, 3, 4, 5, 6], 4)
    assert loop.stats().rejected == 1
    # misaligned shared prefix is a programmer error, not a reject
    with pytest.raises(ValueError, match="shared_len"):
        loop.submit([1], 2, shared_pages=(0,), shared_len=3)


def test_pool_exhaustion_defers_admission():
    # each request needs ceil((4+4-1)/4)=2 pages of a 5-page pool —
    # the third stays QUEUED until a completion frees pages (upfront
    # reservation: admitted requests never deadlock)
    cfg = KVPoolConfig(n_pages=5, page_size=4, n_kv_heads=2, head_dim=4,
                       n_replicas=2, dtype="float32")
    pool = _pool(cfg)
    loop = ServeLoop(pool, ToyLM(cfg), n_slots=4, max_pages=2,
                     queue_capacity=8)
    reqs = [loop.submit([1, 2, 3, 4], 4) for _ in range(3)]
    st = loop.tick()
    assert st.admitted == 2 and st.queue_depth == 1
    assert reqs[2].state is RequestState.QUEUED
    assert pool.free_pages == 1            # 4 reserved, 1 short of 2
    assert loop.drain(timeout=120)
    assert all(r.state is RequestState.DONE for r in reqs)
    assert loop.stats().admitted == 3
    assert pool.pages_in_use == 0          # leak-free


def test_unserveable_head_raises_instead_of_spinning():
    cfg = KVPoolConfig(n_pages=2, page_size=4, n_kv_heads=2, head_dim=4,
                       n_replicas=2, dtype="float32")
    pool = _pool(cfg)
    loop = ServeLoop(pool, ToyLM(cfg), n_slots=2, max_pages=4,
                     queue_capacity=4)
    loop.submit([1] * 8, 5)                # needs 3 pages, only 2 exist
    with pytest.raises(RuntimeError, match="pages"):
        loop.tick()


def test_deadline_expiry():
    pool = _pool()
    loop = ServeLoop(pool, ToyLM(CFG), n_slots=1, max_pages=4,
                     queue_capacity=4)
    blocker = loop.submit([1], 6)
    late = loop.submit([2], 2, deadline_tick=1)
    loop.tick()                            # blocker admitted, late queued
    loop.tick()
    st = loop.tick()                       # tick 2 > deadline 1: expired
    assert late.state is RequestState.EXPIRED and st.expired == 1
    assert loop.drain(timeout=120)
    assert blocker.state is RequestState.DONE
    assert late.generated == []


def test_min_request_completes_in_one_tick():
    pool = _pool()
    loop = ServeLoop(pool, ToyLM(CFG), n_slots=2, max_pages=4)
    req = loop.submit([5], 1)
    st = loop.tick()
    assert req.state is RequestState.DONE
    assert len(req.generated) == 1 and st.completed == 1
    assert req.generated[0] == ToyLM(CFG).next_token((5,))


def test_write_back_plane_rejected():
    pool = SELCCKVPool(CFG)
    pool.open_rounds_plane(write_back=True)
    with pytest.raises(ValueError, match="write-through"):
        ServeLoop(pool, ToyLM(CFG))
    with pytest.raises(ValueError, match="rounds plane"):
        ServeLoop(SELCCKVPool(CFG), ToyLM(CFG))


# ---------------------------------------------------------- counters

def test_stats_snapshot_counts():
    pool = _pool()
    model = ToyLM(CFG, n_q_heads=4)
    loop = ServeLoop(pool, model, n_slots=2, max_pages=4,
                     prefill_chunk=2)
    loop.submit([1, 2, 3], 3)              # 2 prefill rows + 3 decode
    loop.submit([4], 2)                    # 2 decode rows
    st0 = loop.tick()
    assert st0.active_slots == 2 and st0.admitted == 2
    assert st0.pages_in_use == 2 + 1      # kv_len 5 -> 2 pages, 2 -> 1
    assert st0.last_rounds > 0
    assert loop.drain(timeout=120)
    st = loop.stats()
    # KV rows = kv_len per request (no shared prefix): (3+3-1)+(1+2-1)
    assert st.appended_tokens == 5 + 2
    assert st.completed == 2 and st.active_slots == 0
    assert st.queue_depth == 0 and st.pages_in_use == 0
    assert st.free_pages == CFG.n_pages
    assert st.attend_calls > 0 and st.rounds_total >= st.last_rounds
    assert st.expired == 0 and st.rejected == 0


def test_background_thread_serves():
    pool = _pool()
    loop = ServeLoop(pool, ToyLM(CFG), n_slots=2, max_pages=4,
                     queue_capacity=16)
    loop.start()
    try:
        reqs = [loop.submit([i + 1, i + 2], 3) for i in range(6)]
        assert loop.drain(timeout=120)
    finally:
        loop.stop()
    assert all(r.state is RequestState.DONE for r in reqs)
    assert loop.stats().completed == 6
    with pytest.raises(RuntimeError, match="already started"):
        loop.start()
        loop.start()
    loop.stop()


# ------------------------------------------------- differential trace

def _run_differential(mesh=None, cfg=CFG):
    model = ToyLM(cfg, n_q_heads=4)
    prefix_tokens = list(range(cfg.page_size))

    # --- engine, with per-completion KV readback against the numpy
    # oracle (only written positions are comparable: recycled pages
    # keep the previous tenant's bytes by design)
    pool_e = _pool(cfg, mesh=mesh)
    shared_e = _shared_prefix(pool_e, model, prefix_tokens)
    readbacks = []

    def on_complete(req, slot):
        kp, vp, wr = model.expected_pages(req)
        k, v, _ = pool_e.read(slot.replica,
                              np.asarray(slot.pages, np.int32))
        np.testing.assert_array_equal(np.asarray(k, np.float32)[wr],
                                      kp[wr])
        np.testing.assert_array_equal(np.asarray(v, np.float32)[wr],
                                      vp[wr])
        # the slot's final fused-attend output matches the paged
        # kernel over the oracle bytes
        if slot.last_attn is not None:
            full_k = np.concatenate(
                [np.stack([np.stack(model.kv(t, i))
                           for i, t in enumerate(prefix_tokens)])
                 [None, :, 0], kp]) if req.shared_pages else kp
            full_v = np.concatenate(
                [np.stack([np.stack(model.kv(t, i))
                           for i, t in enumerate(prefix_tokens)])
                 [None, :, 1], vp]) if req.shared_pages else vp
            tbl = np.arange(len(full_k), dtype=np.int32)[None]
            q = model.query(req.generated[-2] if len(req.generated) > 1
                            else req.prompt[-1], req.kv_len - 1)[None]
            want = decode_paged(q.astype(np.float32), full_k, full_v,
                                tbl, np.asarray([req.kv_len], np.int32),
                                backend="ref")
            np.testing.assert_allclose(slot.last_attn,
                                       np.asarray(want)[0], rtol=2e-5,
                                       atol=2e-5)
        readbacks.append(req.rid)

    loop = ServeLoop(pool_e, model, n_slots=3, max_pages=4,
                     prefill_chunk=4, queue_capacity=16,
                     on_complete=on_complete)
    trace = _mixed_trace(shared_e)
    ereqs = [loop.submit(p, g, shared_pages=sp, shared_len=sl)
             for p, g, sp, sl in trace]
    assert loop.drain(timeout=240)
    st = loop.stats()
    assert st.completed == len(trace) and len(readbacks) == len(trace)
    assert pool_e.pages_in_use == len(shared_e)      # zero leaked pages

    # --- synchronous oracle on a fresh pool
    pool_o = _pool(cfg, mesh=mesh)
    shared_o = _shared_prefix(pool_o, model, prefix_tokens)
    oreqs = [ServeRequest(prompt=tuple(p), max_new=g,
                          shared_pages=tuple(shared_o) if sp else (),
                          shared_len=sl) for p, g, sp, sl in trace]
    sync = SyncBatchServer(pool_o, model, n_slots=3, max_pages=4)
    sync.serve(oreqs)
    assert pool_o.pages_in_use == len(shared_o)      # oracle leak-free

    for e, o in zip(ereqs, oreqs):
        assert len(e.generated) == e.max_new
        assert e.generated == o.generated, (e.rid, e.generated,
                                            o.generated)
    # the baseline really is the slow path: two dispatches per append
    assert sync.plane_calls == 2 * sync.steps
    return [e.generated for e in ereqs]


def test_differential_trace_flat():
    _run_differential()


def test_differential_trace_4shard_subprocess():
    """The same differential trace on a 4-shard mesh plane: engine and
    oracle both drive the mesh-sharded rounds engine; tokens, KV
    readback, and pool accounting must all hold there too."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        sys.path.insert(0, "tests")
        import jax
        from repro.dsm.kvpool import KVPoolConfig
        import test_serve
        mesh = jax.make_mesh((4,), ("shards",))
        cfg = KVPoolConfig(n_pages=24, page_size=4, n_kv_heads=2,
                           head_dim=4, n_replicas=4, dtype="float32")
        toks = test_serve._run_differential(mesh=mesh, cfg=cfg)
        flat = test_serve._run_differential(cfg=cfg)
        assert toks == flat, "sharded plane diverged from flat"
        print("SERVE_4SHARD_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "SERVE_4SHARD_OK" in out.stdout, out.stderr[-3000:]
