"""Property-based protocol tests (hypothesis): arbitrary op schedules must
preserve sequential consistency + coherence, for the DES protocol AND the
vectorized JAX round protocol."""

import random

import numpy as np

from hypothesis_compat import given, settings, st

from repro.core import (ClusterConfig, SELCCConfig, SELCCLayer,
                        check_sequential_consistency, merge_histories)
from repro.core import jax_protocol as jp
from repro.core.rounds import DevicePlane


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       read_pct=st.integers(0, 100),
       n_gcls=st.integers(2, 64),
       cache=st.integers(2, 64))
def test_des_random_schedules_are_sequentially_consistent(
        seed, read_pct, n_gcls, cache):
    selcc = SELCCConfig(cache_capacity=cache, record_history=True)
    layer = SELCCLayer(ClusterConfig(n_compute=3, n_memory=2,
                                     threads_per_node=3, selcc=selcc,
                                     seed=seed))
    gcls = layer.allocate_many(n_gcls)
    procs = []
    for node in layer.nodes:
        for t in range(3):
            def worker(node=node, t=t,
                       rng=random.Random(seed * 77 + node.node_id * 7
                                         + t)):
                for _ in range(40):
                    g = gcls[rng.randrange(n_gcls)]
                    if rng.randrange(100) < read_pct:
                        yield from node.op_read(g, thread=t)
                    else:
                        yield from node.op_write(g, thread=t)
            procs.append(layer.env.process(worker()))
    layer.env.run_until_complete(procs, hard_limit=500.0)
    check_sequential_consistency(merge_histories(layer.nodes))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       hot_lines=st.integers(2, 32),
       write_pct=st.integers(0, 100))
def test_jax_round_protocol_invariants(seed, hot_lines, write_pct):
    # FIXED array shapes (n_lines=32, R=12) so one jit compilation serves
    # every hypothesis example; contention level varies via hot_lines.
    rng = np.random.default_rng(seed)
    n_nodes = 4
    n_lines = 32
    state = jp.make_state(n_nodes, n_lines)
    for _ in range(6):
        r = 12
        # at most one op per (node, line) per round: sample WITHOUT
        # replacement from the full (node, line) grid, skewed to hot lines
        pairs = [(n, l) for n in range(n_nodes) for l in range(n_lines)]
        weights = np.array([4.0 if l < hot_lines else 0.05
                            for n, l in pairs])
        idx = rng.choice(len(pairs), size=r, replace=False,
                         p=weights / weights.sum())
        nid = np.array([pairs[i][0] for i in idx], np.int32)
        ln = np.array([pairs[i][1] for i in idx], np.int32)
        isw = (rng.integers(0, 100, r) < write_pct).astype(np.int32)
        plane = DevicePlane.open(state, n_nodes=n_nodes,
                                 max_rounds=128)
        plane.ops(nid, ln, isw)
        state = plane.state
        jp.check_invariants(state)


def test_jax_round_versions_monotone_per_line():
    rng = np.random.default_rng(0)
    state = jp.make_state(3, 8)
    last = np.zeros(8, np.int64)
    for _ in range(10):
        nid = rng.integers(0, 3, 8).astype(np.int32)
        ln = np.arange(8).astype(np.int32)
        isw = rng.integers(0, 2, 8).astype(np.int32)
        plane = DevicePlane.open(state, n_nodes=3)
        vers = plane.ops(nid, ln, isw).version
        state = plane.state
        mv = np.asarray(state["mem_version"])
        assert (mv >= last).all()
        last = mv
