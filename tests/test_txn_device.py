"""Differential: fused device transaction CC vs the host ``TxnEngine``.

The device loop (core/rounds/txn.py) serializes a whole batch by
(exec_step, slot): lock hold intervals per line are disjoint, so the
batch is serially equivalent to executing txns one at a time in that
order.  The oracle here IS that serial execution — the DES
``TxnEngine`` replaying the device's EFFECTIVE tuple sets sequentially
in device order, with the device's client timestamps injected
(``engine.run(..., ts=...)``) — and the tests demand bit-identical
commit/abort decisions AND final memory images (host ``GclHeap``
records rendered to lanes vs a protocol-fresh device read-back) for
both 2PL no-wait and TO, on the flat plane and (in a subprocess with 4
virtual devices) the mesh-sharded plane.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.apps.txn import TxnConfig, TxnEngine
from repro.core import ClusterConfig, SELCCLayer

jax = pytest.importorskip("jax")

from repro.apps.txn_device import (DeviceTxnConfig, DeviceTxnEngine,
                                   encode_txns,
                                   host_record_lanes)        # noqa: E402
from repro.apps.workloads import (TxnBatchConfig,
                                  device_txn_batches)        # noqa: E402
from repro.core import rounds as rp                          # noqa: E402
from repro.core.rounds.engine import TRACE_COUNTS            # noqa: E402
from repro.core.rounds.txn import txn_payload_width          # noqa: E402

CFG = TxnBatchConfig(n_gcls=12, tuples_per_gcl=4, batch=8, iters=3,
                     max_group_lines=4, zipf_theta=0.9, n_nodes=3)


def _device_engine(algo, cfg=CFG):
    state = rp.make_state(
        cfg.n_nodes, cfg.n_gcls,
        payload_width=txn_payload_width(cfg.tuples_per_gcl))
    plane = rp.DevicePlane.open(state, n_nodes=cfg.n_nodes)
    dcfg = DeviceTxnConfig(algo=algo,
                           tuples_per_gcl=cfg.tuples_per_gcl,
                           max_group_lines=cfg.max_group_lines)
    return DeviceTxnEngine(plane, dcfg)


def _host_oracle(algo, cfg=CFG):
    # ONE memory node: the host engine latches GCLs in sorted-GAddr
    # (node_id, offset) order, the device in ascending line order; with
    # n_memory=1 the two canonical orders coincide, so TO's abort-time
    # partial-update leak lands in the SAME tuples on both planes
    # (with striping both orders are valid but differ, and the leaked
    # headers differ with them — decisions stay order-independent)
    layer = SELCCLayer(ClusterConfig(n_compute=cfg.n_nodes, n_memory=1,
                                     threads_per_node=4))
    engines = [TxnEngine(layer, nd,
                         TxnConfig(algo=algo,
                                   tuples_per_gcl=cfg.tuples_per_gcl),
                         cfg.n_gcls * cfg.tuples_per_gcl)
               for nd in layer.nodes]
    return layer, engines


def _host_run_one(layer, engine, eff_r, eff_w, ts):
    out = {}

    def one():
        out["ok"] = yield from engine.run(eff_r, eff_w, ts=ts)
    layer.env.run_until_complete([layer.env.process(one())])
    return out["ok"]


def _host_image(layer, engines, cfg=CFG):
    gcls = engines[0].gcls
    return np.stack([
        host_record_lanes(layer.heap.load(gcls[g]), g,
                          cfg.tuples_per_gcl)
        for g in range(cfg.n_gcls)])


def _differential(algo, seed=3):
    dev = _device_engine(algo)
    layer, engines = _host_oracle(algo)
    batches = device_txn_batches(CFG, seed=seed)
    total_retries = total_aborts = 0
    for txns, node, ts in batches:
        res, effective = dev.run_batch(node, txns, ts=ts)
        total_retries += int(res.retries.sum())
        total_aborts += int((~res.decision).sum())
        # replay sequentially in the device's serial order
        order = sorted(range(len(txns)),
                       key=lambda i: (int(res.exec_step[i]), i))
        host_dec = {}
        for i in order:
            eff_r, eff_w = effective[i]
            host_dec[i] = _host_run_one(layer, engines[int(node[i])],
                                        eff_r, eff_w, int(ts[i]))
        for i in range(len(txns)):
            assert bool(res.decision[i]) == host_dec[i], \
                (algo, i, int(ts[i]), effective[i])
    np.testing.assert_array_equal(dev.final_image(),
                                  _host_image(layer, engines))
    dev.plane.check()
    return total_retries, total_aborts


def test_differential_2pl_decisions_and_image():
    retries, aborts = _differential("2pl")
    assert aborts == 0          # no-wait retries in-loop until commit
    assert retries > 0          # ...and the workload does conflict
    # host-parity accounting: retries surface as nowait abort attempts
    # (satellite: TxnStats carries abort reasons + latency percentiles)


def test_differential_to_decisions_and_image():
    retries, aborts = _differential("to")
    assert aborts > 0           # shuffled client ts: TO really aborts


def test_txn_stats_reasons_and_percentiles():
    dev = _device_engine("to")
    txns, node, ts = device_txn_batches(CFG, seed=3)[0]
    res, _ = dev.run_batch(node, txns, ts=ts)
    s = dev.stats
    assert s.commits == int(res.decision.sum())
    assert s.abort_reasons.get("ts", 0) == int((~res.decision).sum())
    assert s.abort_reasons.get("nowait", 0) == int(res.retries.sum())
    assert s.latency.count == len(txns)
    assert 0 < s.p50 <= s.p99


def test_encode_txns_trim_policy():
    cfg = DeviceTxnConfig(tuples_per_gcl=4, max_group_lines=2)
    # 3 write gcls (0, 2, 5) + read gcl 7: writes win, lowest first
    glines, rmask, wmask, eff = encode_txns(
        [([28, 1], [0, 8, 20, 1])], cfg)
    assert glines.tolist() == [[0, 2]]
    eff_r, eff_w = eff[0]
    assert eff_w == [0, 1, 8] and eff_r == [1]      # gcl 5, 7 trimmed
    assert wmask[0, 0].tolist() == [1, 1, 0, 0]     # tuples 0, 1
    assert wmask[0, 1].tolist() == [1, 0, 0, 0]     # tuple 8
    assert rmask.sum() == 0   # read 1 is in the write set: wmask wins
    # untrimmed txn: read/write masks disjoint, reads kept
    glines, rmask, wmask, eff = encode_txns([([4, 5], [9])], cfg)
    assert glines.tolist() == [[1, 2]]
    assert eff[0] == ([4, 5], [9])
    assert rmask[0, 0].tolist() == [1, 1, 0, 0]
    assert wmask[0, 1].tolist() == [0, 1, 0, 0]


def test_host_driven_scheduler_matches_fused():
    """``run_txn_batch_host`` (the pre-fuse benchmark baseline) IS the
    fused loop driven from the host: bit-identical result fields and
    final plane state, both algos."""
    for algo in ("2pl", "to"):
        fused = _device_engine(algo)
        host = _device_engine(algo)
        txns, node, ts = device_txn_batches(CFG, seed=5)[0]
        rf, _ = fused.run_batch(node, txns, ts=ts)
        glines, rmask, wmask, _ = encode_txns(txns, host.cfg)
        rh = rp.run_txn_batch_host(host.plane, node, glines, rmask,
                                   wmask, np.asarray(ts, np.int32),
                                   algo=algo)
        for fld in ("decision", "exec_step", "retries"):
            np.testing.assert_array_equal(
                np.asarray(getattr(rf, fld)),
                np.asarray(getattr(rh, fld)), err_msg=f"{algo}:{fld}")
        for k, v in fused.plane.state.items():
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(host.plane.state[k]),
                err_msg=f"{algo}:{k}")


def test_txn_loop_compiles_once_per_shape():
    dev = _device_engine("2pl")
    key_of = lambda: {k: v for k, v in TRACE_COUNTS.items()
                      if k[0] == "txn" and k[1] == "2pl"}
    batches = device_txn_batches(CFG, seed=11)
    dev.run_batch(batches[0][1], batches[0][0], ts=batches[0][2])
    after_one = key_of()
    assert sum(after_one.values()) >= 1
    dev.run_batch(batches[1][1], batches[1][0], ts=batches[1][2])
    assert key_of() == after_one     # same shape: ZERO new traces


def test_flat_vs_sharded_txn_subprocess():
    """The mesh-sharded txn loop serializes EXACTLY like the flat one:
    same decisions, same serial order, same retries, same final memory
    image, both algos, on 4 virtual devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, numpy as np
        from repro.apps.txn_device import DeviceTxnConfig, DeviceTxnEngine
        from repro.apps.workloads import TxnBatchConfig, device_txn_batches
        from repro.core import rounds as rp
        from repro.core.rounds.txn import txn_payload_width

        cfg = TxnBatchConfig(n_gcls=12, tuples_per_gcl=4, batch=8,
                             iters=2, max_group_lines=4,
                             zipf_theta=0.9, n_nodes=4)
        mesh = jax.make_mesh((4,), ("shards",))
        W = txn_payload_width(cfg.tuples_per_gcl)

        for algo in ("2pl", "to"):
            dcfg = DeviceTxnConfig(algo=algo,
                                   tuples_per_gcl=cfg.tuples_per_gcl,
                                   max_group_lines=cfg.max_group_lines)
            flat = DeviceTxnEngine(rp.DevicePlane.open(
                rp.make_state(cfg.n_nodes, cfg.n_gcls,
                              payload_width=W)), dcfg)
            shd = DeviceTxnEngine(rp.DevicePlane.open(
                rp.make_sharded_state(cfg.n_nodes, cfg.n_gcls, mesh,
                                      payload_width=W), mesh), dcfg)
            saw_abort = saw_retry = 0
            for txns, node, ts in device_txn_batches(cfg, seed=7):
                r1, _ = flat.run_batch(node, txns, ts=ts)
                r2, _ = shd.run_batch(node, txns, ts=ts)
                assert r1.decision.tolist() == r2.decision.tolist(), algo
                assert r1.exec_step.tolist() == r2.exec_step.tolist()
                assert r1.retries.tolist() == r2.retries.tolist()
                saw_abort += int((~r1.decision).sum())
                saw_retry += int(r1.retries.sum())
            for k, v in flat.plane.state.items():
                np.testing.assert_array_equal(
                    np.asarray(v), np.asarray(shd.plane.flat_state()[k]),
                    err_msg=f"{algo}:{k}")
            shd.plane.check()
            assert saw_retry > 0, algo
            if algo == "to":
                assert saw_abort > 0
        print("TXN_SHARDED_PARITY_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "TXN_SHARDED_PARITY_OK" in out.stdout, out.stderr[-3000:]
