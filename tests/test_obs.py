"""Observability plane (src/repro/obs/): the StreamingHistogram sketch
against numpy.percentile, the EWMA closed form, the FlightRecorder span
ring (wraparound, zero added jit traces), the Chrome-trace / Prometheus
exporters (parse-back), placement driven purely off recorder heat, the
serving loop's queue-wait / TPOT histograms — and THE acceptance
differential: a mixed-verb trace on a flat plane vs a 4-shard plane
must produce bit-identical per-line hit/write-hit telemetry (runs in a
subprocess with 4 virtual devices, like test_congestion's).
"""

import json
import re
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.rounds.placement import plan_rehome
from repro.obs import (EwmaHeat, FlightRecorder, MetricsRegistry,
                       PlaneTelemetry, StreamingHistogram)

jax = pytest.importorskip("jax")

import jax.numpy as jnp                                  # noqa: E402

from repro.core import rounds as rp                      # noqa: E402
from repro.core.rounds import engine                     # noqa: E402


def _i32(*xs):
    return np.asarray(xs, np.int32)


def _tele(line_hits, line_whits=None, n_shards=4):
    """Hand-built PlaneTelemetry for recorder/placement unit tests."""
    hits = np.asarray(line_hits, np.int64)
    served = np.zeros(n_shards, np.int64)
    served[0] = hits.sum()
    return PlaneTelemetry.from_counters({
        "occupancy": np.zeros((n_shards, n_shards), np.int64),
        "deferred": np.zeros((n_shards, n_shards), np.int64),
        "served_per_home": served,
        "replica_served": np.zeros(n_shards, np.int64),
        "line_hits": hits,
        "line_whits": (np.zeros_like(hits) if line_whits is None
                       else np.asarray(line_whits, np.int64)),
    })


# ----------------------------------------------------------- histogram

def test_histogram_tracks_numpy_percentile():
    """The sketch's bounded relative error, checked on a fixed heavy-
    tailed draw: p50/p90/p99 within a few percent of the exact sorted-
    sample answer, ends exact."""
    rng = np.random.default_rng(42)
    xs = rng.lognormal(mean=-6.0, sigma=1.5, size=5000)
    h = StreamingHistogram()
    for x in xs:
        h.observe(x)
    assert h.count == len(xs)
    assert h.min == xs.min() and h.max == xs.max()
    assert h.mean == pytest.approx(xs.mean())
    for q in (0.50, 0.90, 0.99):
        exact = float(np.percentile(xs, q * 100))
        assert h.quantile(q) == pytest.approx(exact, rel=0.05), q
    assert h.quantile(0.0) == xs.min()
    assert h.quantile(1.0) == xs.max()
    assert h.percentile(50) == h.quantile(0.50)


def test_histogram_edges_and_merge():
    h = StreamingHistogram()
    assert h.quantile(0.5) == 0.0 and h.snapshot()["count"] == 0
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        StreamingHistogram(growth=1.0)
    a, b = StreamingHistogram(), StreamingHistogram()
    for x in (1.0, 2.0, 3.0):
        a.observe(x)
    b.observe(10.0)
    a.merge(b)
    assert a.count == 4 and a.max == 10.0
    assert a.total == pytest.approx(16.0)
    with pytest.raises(ValueError, match="geometry"):
        a.merge(StreamingHistogram(growth=2.0))


# ---------------------------------------------------------------- EWMA

def test_ewma_closed_form():
    """k updates with constant counts c from zero must equal the closed
    form c * (1 - (1-alpha)^k) exactly (float64 arithmetic)."""
    alpha, k = 0.3, 6
    c = np.asarray([5.0, 2.0, 0.0, 7.0])
    heat = EwmaHeat(4, alpha=alpha)
    for _ in range(k):
        heat.update(c)
    np.testing.assert_allclose(heat.values,
                               c * (1 - (1 - alpha) ** k),
                               rtol=1e-12)
    assert heat.updates == k
    assert heat.top(2).tolist() == [3, 0]
    with pytest.raises(ValueError, match="shape"):
        heat.update(np.zeros(3))
    with pytest.raises(ValueError, match="alpha"):
        EwmaHeat(4, alpha=0.0)


# ------------------------------------------------------- recorder ring

def test_recorder_ring_wraparound():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record("ops", duration=1e-4, batch=(8,), rounds=i)
    assert len(rec) == 4 and rec.total == 10 and rec.dropped == 6
    spans = rec.spans()
    assert [s.index for s in spans] == [6, 7, 8, 9]   # oldest first
    assert [s.rounds for s in spans] == [6, 7, 8, 9]
    # counters saw EVERY span, not just the retained window
    c = rec.registry.counter("plane_dispatches_total",
                             labels={"verb": "ops"})
    assert c.value == 10
    assert rec.snapshot()["dropped"] == 6
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_recorder_heat_drives_plan_rehome():
    """The ISSUE acceptance: placement planned PURELY off the
    recorder's EWMA heat — no raw telemetry plumbing.  Constant skewed
    hits -> heat is a positive scalar multiple of the hit vector, so
    the greedy plan matches the raw-counter plan exactly."""
    l, s = 16, 4
    hits = np.zeros(l, np.int64)
    hits[[0, 4, 8]] = [90, 60, 30]         # identity perm: all on shard 0
    hits[[1, 5]] = [2, 1]
    rec = FlightRecorder(capacity=16)
    for _ in range(3):
        rec.record("ops", duration=1e-4, batch=(8,), rounds=2,
                   telemetry=_tele(hits, n_shards=s))
    heat = rec.line_heat
    assert heat is not None and heat.shape == (l,)
    assert rec.home_heat is not None and rec.home_heat.shape == (s,)
    perm = np.arange(l)
    lines, homes, victims = plan_rehome(heat, perm, s, max_moves=8,
                                        min_gain=0.5)
    ref = plan_rehome(hits, perm, s, max_moves=8)
    assert lines.tolist() == ref[0].tolist()
    assert homes.tolist() == ref[1].tolist()
    assert 0 not in set(homes.tolist())
    # and plan_rehome takes the typed telemetry itself (duck-typed)
    lines2, _, _ = plan_rehome(_tele(hits, n_shards=s), perm, s,
                               max_moves=8)
    assert lines2.tolist() == ref[0].tolist()


# ------------------------------------------------------------ exporters

def test_chrome_trace_export_is_valid(tmp_path):
    rec = FlightRecorder(capacity=8)
    rec.record("ops", duration=2e-3, batch=(4,), rounds=3,
               telemetry=_tele([1, 0, 2, 0], n_shards=1))
    rec.record("txn", duration=1e-3, batch=(2, 3), rounds=5,
               attrs={"algo": "2pl"})
    path = tmp_path / "trace.json"
    doc = rec.export_chrome_trace(str(path))
    parsed = json.loads(path.read_text())
    assert parsed == doc
    evs = parsed["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X" and ev["cat"] == "plane"
        assert ev["dur"] > 0 and ev["ts"] >= 0
        assert {"rounds", "served", "deferred", "batch",
                "dispatch"} <= set(ev["args"])
    assert evs[0]["name"] == "ops" and evs[0]["args"]["served"] == 3
    assert evs[1]["args"]["algo"] == "2pl"
    assert evs[1]["args"]["batch"] == [2, 3]
    assert parsed["otherData"]["spans_total"] == 2


_PROM_LINE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})? (\S+)$")


def _parse_prom(text):
    """Minimal Prometheus text-format parser: sample lines back into
    {(name, labelstr): float}; validates every non-comment line."""
    out = {}
    for line in text.strip().splitlines():
        if line.startswith("#"):
            assert line.startswith(("# HELP ", "# TYPE "))
            continue
        m = _PROM_LINE.match(line)
        assert m, f"unparseable exposition line: {line!r}"
        out[(m.group(1), m.group(2) or "")] = float(m.group(3))
    return out


def test_prometheus_render_parses_back():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "requests", {"verb": "ops"}).inc(7)
    reg.gauge("depth").set(3.5)
    h = reg.histogram("lat_seconds", "latency")
    for x in (0.001, 0.002, 0.004, 0.008):
        h.observe(x)
    samples = _parse_prom(reg.render_prom())
    assert samples[("reqs_total", '{verb="ops"}')] == 7.0
    assert samples[("depth", "")] == 3.5
    assert samples[("lat_seconds_count", "")] == 4.0
    assert samples[("lat_seconds_sum", "")] == pytest.approx(0.015)
    buckets = [(k, v) for k, v in samples.items()
               if k[0] == "lat_seconds_bucket"]
    assert len(buckets) == 5               # 4 occupied + +Inf
    cums = [v for _, v in buckets]
    assert cums == sorted(cums)            # cumulative, monotone
    assert any('le="+Inf"' in k[1] and v == 4.0 for k, v in buckets)
    # kind conflicts are an error, not silent re-registration
    with pytest.raises(ValueError, match="registered"):
        reg.gauge("reqs_total")


# --------------------------------------------- plane integration (jax)

def test_plane_spans_add_zero_jit_traces():
    """Warm every shape recorder-OFF, snapshot TRACE_COUNTS, re-run the
    same mixed verbs recorder-ON: the trace-key set must not grow and
    every span's compile delta must be 0 — the recorder is host-side
    by construction."""
    plane = rp.DevicePlane.open(rp.make_state(2, 4, payload_width=1),
                                n_nodes=2)

    def _store(data, line, val):
        return jnp.where((line >= 0)[:, None], val, data)

    def drive():
        plane.ops(_i32(0, 1), _i32(0, 1), _i32(1, 0),
                  np.asarray([[5], [0]], np.int32))
        plane.rmw(_i32(1), _i32(0), modify=_store,
                  operands=(np.asarray([[9]], np.int32),))
        plane.evict(_i32(1), _i32(0))

    drive()                                # recorder off: warm traces
    keys_before = set(engine.TRACE_COUNTS)
    rec = FlightRecorder(capacity=16)
    plane.attach_recorder(rec)
    drive()
    assert set(engine.TRACE_COUNTS) == keys_before, \
        "attaching the recorder minted new jit traces"
    assert rec.total == 3
    ops_s, rmw_s, evict_s = rec.spans()
    assert (ops_s.verb, rmw_s.verb, evict_s.verb) == \
        ("ops", "rmw", "evict")
    assert all(s.compiled == 0 for s in rec.spans())
    assert ops_s.served == 2 and ops_s.batch == (2,)
    assert rmw_s.served == 2               # 1 op, read phase + write phase
    assert evict_s.served == 0             # no telemetry on evict
    assert rec.line_heat is not None and rec.line_heat.shape == (4,)
    assert rec.line_heat[0] > rec.line_heat[2]
    reg = rec.registry
    assert reg.counter("plane_dispatches_total",
                       labels={"verb": "ops"}).value == 1
    assert reg.counter("plane_compile_events_total").value == 0
    assert "plane_dispatch_seconds_bucket" in reg.render_prom()
    plane.check()


def test_serve_loop_histograms():
    """Satellite (f): ServeStats carries queue-wait and TPOT histogram
    snapshots, and the loop's registry renders them as Prometheus."""
    from repro.dsm.kvpool import KVPoolConfig, SELCCKVPool
    from repro.serve import ServeLoop, ToyLM
    cfg = KVPoolConfig(n_pages=24, page_size=4, n_kv_heads=2,
                       head_dim=4, n_replicas=2, dtype="float32")
    pool = SELCCKVPool(cfg)
    pool.open_rounds_plane()
    rec = FlightRecorder(capacity=64)
    loop = ServeLoop(pool, ToyLM(cfg), n_slots=2, max_pages=4,
                     queue_capacity=8, recorder=rec)
    reqs = [loop.submit([1, 2], 3) for _ in range(3)]
    assert loop.drain(timeout=120)
    assert all(r.generated for r in reqs)
    st = loop.stats()
    assert st.queue_wait is not None and st.queue_wait["count"] == 3
    assert st.queue_wait["max"] >= st.queue_wait["min"] >= 0.0
    # 3 reqs x 3 tokens: 2 inter-token gaps each
    assert st.tpot is not None and st.tpot["count"] == 6
    assert st.tpot["p99"] >= st.tpot["p50"] > 0.0
    prom = loop.render_prom()
    assert "serve_queue_wait_seconds_count 3" in prom
    assert "serve_tpot_seconds_count 6" in prom
    assert rec.total > 0                   # plane spans flowed too
    assert {"rmw"} <= set(rec.snapshot()["verbs"])


# ------------------------------ parity differential (4 devices)

def test_telemetry_parity_flat_vs_sharded_subprocess():
    """THE acceptance test: a mixed-verb trace (ops reads+writes, RMW)
    on a flat plane and on a 4-shard plane yields BIT-IDENTICAL
    per-line hit/write-hit telemetry — the counters are protocol
    facts, not geometry artifacts."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import rounds as rp

        N_NODES, N_LINES = 4, 8
        mesh = jax.make_mesh((4,), ("shards",))
        flat = rp.DevicePlane.open(
            rp.make_state(N_NODES, N_LINES, payload_width=1),
            n_nodes=N_NODES)
        shd = rp.DevicePlane.open(
            rp.make_sharded_state(N_NODES, N_LINES, mesh,
                                  payload_width=1),
            mesh, n_nodes=N_NODES)

        def _store(data, line, val):
            return jnp.where((line >= 0)[:, None], val, data)

        TRACE = [
            ("ops", [0, 1, 2, 3], [0, 1, 2, 3], [1, 1, 1, 1]),
            ("ops", [0, 1, 2, 3], [0, 0, 4, 4], [0, 0, 0, 0]),
            ("rmw", [1, 2], [1, 5], None, None),
            ("ops", [3, 0, 1], [5, 2, 7], [0, 1, 0]),
            ("rmw", [0, 3], [0, 3], None, None),
            ("ops", [2, 3, 0, 1], [6, 5, 1, 4], [1, 0, 0, 1]),
        ]
        agg = {"flat": 0, "shd": 0}
        for b, batch in enumerate(TRACE):
            if batch[0] == "ops":
                _, node, line, isw = batch
                node, line, isw = (np.asarray(node, np.int32),
                                   np.asarray(line, np.int32),
                                   np.asarray(isw, np.int32))
                wd = np.where(isw[:, None] > 0, b * 8 + line[:, None],
                              0).astype(np.int32)
                rf = flat.ops(node, line, isw, wd, max_rounds=128)
                rs = shd.ops(node, line, isw, wd, max_rounds=128)
            else:
                _, node, line = batch[:3]
                node, line = (np.asarray(node, np.int32),
                              np.asarray(line, np.int32))
                val = (100 + b * 8 + line[:, None]).astype(np.int32)
                rf = flat.rmw(node, line, modify=_store,
                              operands=(val,), max_rounds=128)
                rs = shd.rmw(node, line, modify=_store,
                             operands=(val,), max_rounds=128)
            assert rf.version.tolist() == rs.version.tolist(), b
            assert rf.data.tolist() == rs.data.tolist(), b
            tf, ts = rf.telemetry, rs.telemetry
            assert tf.n_shards == 1 and ts.n_shards == 4
            assert tf.line_hits.tolist() == ts.line_hits.tolist(), b
            assert tf.line_whits.tolist() == ts.line_whits.tolist(), b
            assert tf.served == ts.served, b
            assert int(ts.served_per_home.sum()) == ts.served
            agg["flat"] += tf.line_hits.sum()
            agg["shd"] += ts.line_hits.sum()
            flat.check(); shd.check()
        assert agg["flat"] == agg["shd"] > 0
        print("OBS_PARITY_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "OBS_PARITY_OK" in out.stdout, out.stderr[-3000:]
