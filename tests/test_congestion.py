"""Congestion paths of the sharded plane: telemetry counters, dynamic
re-homing, and read-replica lines (core/rounds/{sharded,placement}.py,
DevicePlane.rehome/replicate).

In-process tests run on a 1-shard mesh (the counters, the replica
serve/invalidate cycle, the slab-row exchange and its trace count are
all real there); the migration differential — flat oracle vs a 4-shard
plane that re-homes hot lines MID-STREAM — runs in a subprocess with 4
virtual devices, asserting bit-identical version histories and payload
images (the ISSUE 9 acceptance trace).
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.rounds.placement import plan_rehome, plan_replication

jax = pytest.importorskip("jax")

from repro.core import rounds as rp                      # noqa: E402
from repro.core.rounds import engine                     # noqa: E402

# Determinism: per batch a line has either concurrent readers or exactly
# one writer (same constraint as tests/test_sharded_rounds.TRACE), so
# version histories are insensitive to how overflow splits a batch
# across rounds — which is exactly what re-homing perturbs.
TRACE = [
    [(0, 0, 0), (1, 0, 0), (2, 1, 0), (3, 2, 0)],
    [(0, 0, 1), (3, 3, 1), (2, 2, 1)],
    [(1, 0, 0), (2, 0, 0), (0, 4, 0), (2, 1, 1)],
    [(0, 0, 1), (1, 1, 1), (3, 5, 1)],
    [(1, 0, 0), (2, 2, 0), (0, 1, 0), (3, 4, 0)],
    [(2, 3, 1), (1, 5, 1), (0, 2, 1)],
    [(n, l, 0) for n, l in zip(range(4), (0, 1, 2, 3))]
    + [(0, 4, 0), (1, 5, 0)],
]
N_NODES, N_LINES = 4, 8


def _mesh1():
    return jax.make_mesh((1,), ("shards",))


def _i32(*xs):
    return np.asarray(xs, np.int32)


# ------------------------------------------------------- telemetry

def test_hot_home_overflow_reports_telemetry():
    """bucket_cap=1 under 4 ops: the fused loop defers and respins, and
    the carry-accumulated counters surface it — deferrals > 0, every op
    accounted for in served_per_home, per-line hit/write-hit counts."""
    mesh = _mesh1()
    state = rp.make_sharded_state(2, 8, mesh)
    plane = rp.DevicePlane.open(state, mesh, n_nodes=2, bucket_cap=1,
                                max_rounds=128)
    res = plane.ops(_i32(0, 1, 0, 1), _i32(0, 0, 1, 1),
                    _i32(1, 1, 1, 1))
    s = res.telemetry
    assert sorted(s) == ["deferred", "line_hits", "line_whits",
                         "occupancy", "replica_served",
                         "served_per_home"]
    assert s["occupancy"].shape == s["deferred"].shape == (1, 1)
    # one bucket slot per round for 4 ops: at least 3 deferrals
    assert int(s["deferred"].sum()) >= 3
    assert int(s["occupancy"].sum()) >= 4          # every op sent once+
    assert s["served_per_home"].tolist() == [4]
    assert int(s["replica_served"].sum()) == 0     # no replica plane
    assert s["line_hits"].tolist() == [2, 2, 0, 0, 0, 0, 0, 0]
    assert s["line_whits"].tolist() == [2, 2, 0, 0, 0, 0, 0, 0]
    plane.check()
    # reads don't count as write hits
    res = plane.ops(_i32(0, 1), _i32(2, 3), _i32(0, 0))
    assert res.telemetry["line_hits"].tolist() == [0, 0, 1, 1, 0, 0, 0, 0]
    assert int(res.telemetry["line_whits"].sum()) == 0


def test_txn_batch_carries_telemetry():
    from repro.core.rounds.txn import txn_payload_width
    mesh = _mesh1()
    w = txn_payload_width(1)
    state = rp.make_sharded_state(2, 4, mesh, payload_width=w)
    plane = rp.DevicePlane.open(state, mesh, n_nodes=2)
    out = plane.txn(_i32(0, 1), np.asarray([[0], [1]], np.int32),
                    np.ones((2, 1, 1), np.int32),
                    np.ones((2, 1, 1), np.int32), _i32(1, 2),
                    algo="2pl")
    assert out.decision.all()
    assert int(out.telemetry["served_per_home"].sum()) > 0
    assert out.telemetry["line_hits"].shape == (4,)


# ------------------------------------------------------- re-homing

def test_rehome_exchange_moves_slab_rows_coherently():
    """Swapping two physical slots permutes every line-indexed leaf and
    installs the new directory; the line-major view (unstripe through
    the directory) is unchanged, so the protocol state is untouched."""
    mesh = _mesh1()
    state = rp.make_sharded_state(2, 4, mesh, payload_width=1,
                                  home_directory=True)
    plane = rp.DevicePlane.open(state, mesh, n_nodes=2)
    plane.ops(_i32(0, 0, 0, 0), _i32(0, 1, 2, 3), _i32(1, 1, 1, 1),
              np.asarray([[10], [11], [12], [13]], np.int32))
    before = {k: np.asarray(v).copy()
              for k, v in plane.flat_state().items()}
    new_home = _i32(1, 0, 2, 3)
    moved = tuple(sorted(k for k in plane.state
                         if k not in ("home",)))
    key = ("rehome", 1, 4, 2, moved, False)
    for _ in range(2):                     # same shape: ONE trace
        plane.state = rp.rehome_exchange(
            plane.state, _i32(0, 1), _i32(1, 0), new_home, mesh=mesh)
        new_home = _i32(0, 1, 2, 3)        # swap back on 2nd pass
    assert engine.TRACE_COUNTS.get(key, 0) == 1, \
        "rehome exchange must trace once per shape"
    plane.check()
    after = plane.flat_state()
    for k in before:
        np.testing.assert_array_equal(np.asarray(after[k]), before[k],
                                      err_msg=k)
    # and the protocol still runs on the migrated layout
    res = plane.ops(_i32(1, 1), _i32(0, 1), _i32(0, 0))
    assert res.data[:, 0].tolist() == [10, 11]
    plane.check()


def test_rehome_verb_guards():
    mesh = _mesh1()
    plane = rp.DevicePlane.open(rp.make_sharded_state(2, 4, mesh),
                                mesh, n_nodes=2)
    with pytest.raises(ValueError, match="home-directory"):
        plane.rehome([0], [0])
    state = rp.make_sharded_state(2, 4, mesh, home_directory=True)
    plane = rp.DevicePlane.open(state, mesh, n_nodes=2)
    with pytest.raises(ValueError, match="out of range"):
        plane.rehome([0], [3])             # 1 shard: only home 0
    assert plane.rehome([0], [0]) == 0     # already home: no-op


def test_plan_rehome_greedy_balances_load():
    n_shards = 4
    l = 16
    perm = np.arange(l)                    # identity: home = line % 4
    hits = np.zeros(l, np.int64)
    hits[[0, 4, 8]] = [90, 60, 30]         # all hot lines home on 0
    hits[[1, 5]] = [2, 1]
    lines, homes, victims = plan_rehome(hits, perm, n_shards,
                                        max_moves=8)
    assert len(lines) > 0
    assert 0 not in set(homes.tolist())    # moves go OFF the hot shard
    for a, h, v in zip(lines, homes, victims):
        assert perm[a] % n_shards == 0     # hot shard donates
        assert perm[v] % n_shards == h     # victim lives on the target
    # applying the plan strictly shrinks the max/min load gap
    home = perm % n_shards
    loads0 = np.bincount(home, weights=hits, minlength=n_shards)
    for a, h, v in zip(lines, homes, victims):
        home[a], home[v] = h, 0
    loads1 = np.bincount(home, weights=hits, minlength=n_shards)
    assert loads1.max() - loads1.min() < loads0.max() - loads0.min()
    # no gain -> empty plan
    ln, _, _ = plan_rehome(np.ones(l, np.int64), perm, n_shards)
    assert ln.size == 0


def test_plan_replication_picks_read_mostly_lines():
    hits = np.asarray([100, 80, 50, 3, 0])
    whits = np.asarray([0, 30, 1, 0, 0])
    picks = plan_replication(hits, whits, top_k=2, max_write_frac=0.05)
    assert picks.tolist() == [0, 2]        # 1 writes too much, 4 cold
    assert plan_replication(hits, whits, top_k=0).size == 0


# ---------------------------------------------------- read replicas

def test_replicated_line_serves_locally_and_invalidates_on_write():
    mesh = _mesh1()
    state = rp.make_sharded_state(3, 4, mesh, payload_width=1,
                                  replicas=True)
    plane = rp.DevicePlane.open(state, mesh, n_nodes=3)
    plane.ops(_i32(0), _i32(0), _i32(1), np.asarray([[7]], np.int32))
    plane.evict(_i32(0), _i32(0))          # drop the M holder
    plane.replicate([0])
    assert bool(np.asarray(plane.state["replica_ok"])[0])
    res = plane.ops(_i32(1, 2), _i32(0, 0), _i32(0, 0))
    assert int(res.telemetry["replica_served"].sum()) == 2
    assert res.version.tolist() == [1, 1]
    assert res.data[:, 0].tolist() == [7, 7]
    # replica-served reads never hit the home slot
    assert int(res.telemetry["line_hits"].sum()) == 0
    plane.check()
    # a granted write invalidates through the normal MSI path
    res = plane.ops(_i32(1), _i32(0), _i32(1), np.asarray([[8]],
                                                          np.int32))
    assert not bool(np.asarray(plane.state["replica_ok"])[0])
    plane.check()
    # once the writer releases, the next round's boundary refresh
    # republishes: the first read routes (and republishes), the one
    # after serves the NEW bytes locally
    plane.evict(_i32(1), _i32(0))
    res = plane.ops(_i32(2, 0), _i32(0, 0), _i32(0, 0))
    assert res.version.tolist() == [2, 2]
    assert res.data[:, 0].tolist() == [8, 8]
    assert int(res.telemetry["replica_served"].sum()) == 0
    assert bool(np.asarray(plane.state["replica_ok"])[0])
    res = plane.ops(_i32(2), _i32(0), _i32(0))
    assert res.version.tolist() == [2]
    assert res.data[:, 0].tolist() == [8]
    assert int(res.telemetry["replica_served"].sum()) == 1
    plane.check()
    # replicate(enable=False) drops the mark: reads route again
    plane.replicate([0], enable=False)
    res = plane.ops(_i32(1), _i32(0), _i32(0))
    assert int(res.telemetry["replica_served"].sum()) == 0
    assert int(res.telemetry["line_hits"].sum()) == 1
    plane.check()


def test_replicate_verb_guards():
    mesh = _mesh1()
    plane = rp.DevicePlane.open(rp.make_sharded_state(2, 4, mesh),
                                mesh, n_nodes=2)
    with pytest.raises(ValueError, match="replica-plane"):
        plane.replicate([0])


# ------------------------------- migration differential (4 devices)

def test_rehome_differential_subprocess():
    """THE acceptance test: the TRACE replayed on a flat oracle and a
    4-shard home-directory plane that migrates hot lines MID-STREAM —
    bit-identical version histories, payload bytes, and final images
    (migration moves rows, never protocol state); plus the 4-shard
    replica serve/invalidate cycle and defer-storm telemetry."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, numpy as np
        from repro.core import rounds as rp

        TRACE = {TRACE!r}
        N_NODES, N_LINES = {N_NODES}, {N_LINES}
        mesh = jax.make_mesh((4,), ("shards",))

        def arrays(batch):
            return (np.asarray([b[0] for b in batch], np.int32),
                    np.asarray([b[1] for b in batch], np.int32),
                    np.asarray([b[2] for b in batch], np.int32))

        def wdata(b, batch):
            return np.asarray(
                [[b * 16 + s + 1, n] if w else [0, 0]
                 for s, (n, _, w) in enumerate(batch)], np.int32)

        for write_back in (False, True):
            flat = rp.DevicePlane.open(
                rp.make_state(N_NODES, N_LINES, write_back=write_back,
                              payload_width=2),
                n_nodes=N_NODES)
            shd = rp.DevicePlane.open(
                rp.make_sharded_state(N_NODES, N_LINES, mesh,
                                      write_back=write_back,
                                      payload_width=2,
                                      home_directory=True),
                mesh, n_nodes=N_NODES)
            hits = np.zeros(N_LINES, np.int64)
            for b, batch in enumerate(TRACE):
                node, line, isw = arrays(batch)
                wd = wdata(b, batch)
                rf = flat.ops(node, line, isw, wd, max_rounds=128)
                rs = shd.ops(node, line, isw, wd, max_rounds=128)
                assert rf.version.tolist() == rs.version.tolist(), (
                    write_back, b)
                assert rf.data.tolist() == rs.data.tolist(), (
                    write_back, b)
                hits += rs.telemetry["line_hits"].astype(np.int64)
                shd.check()
                if b == 2:
                    # migrate the observed-hottest lines mid-stream
                    perm = np.asarray(shd.state["home"])
                    lines, homes, victims = rp.plan_rehome(
                        hits, perm, 4, max_moves=4)
                    moved = shd.rehome(lines, homes, victims)
                    assert moved == len(lines)
                    shd.check()
                if b == 4:
                    # and once more without explicit victims
                    moved = shd.rehome(np.asarray([0, 3]),
                                       np.asarray([2, 1]))
                    shd.check()
            perm = np.asarray(shd.state["home"])
            assert sorted(perm.tolist()) == list(range(N_LINES))
            assert (perm != np.arange(N_LINES)).any(), \\
                "no migration happened — differential is vacuous"
            g = shd.flat_state()
            for k in flat.state:
                np.testing.assert_array_equal(
                    np.asarray(flat.state[k]), np.asarray(g[k]),
                    err_msg=f"{{write_back}}:{{k}}")

        # defer storm at 4 shards: all ops to one home, cap 1 — the
        # telemetry rows localize the congestion to that home column
        state = rp.make_sharded_state(4, 8, mesh)
        plane = rp.DevicePlane.open(state, mesh, n_nodes=4,
                                    bucket_cap=1, max_rounds=256)
        R = 16
        node = np.asarray([i % 4 for i in range(R)], np.int32)
        line = np.zeros(R, np.int32)       # all home shard 0
        res = plane.ops(node, line, np.ones(R, np.int32))
        s = res.telemetry
        assert s["deferred"].shape == (4, 4)
        assert int(s["deferred"][:, 0].sum()) > 0
        assert int(s["deferred"][:, 1:].sum()) == 0
        assert s["served_per_home"].tolist() == [R, 0, 0, 0]
        assert int(s["line_hits"][0]) == R
        plane.check()

        # 4-shard replica cycle: remote readers serve from their own
        # shard, a write kills the image, the refresh republishes
        state = rp.make_sharded_state(4, 8, mesh, payload_width=1,
                                      replicas=True,
                                      home_directory=True)
        plane = rp.DevicePlane.open(state, mesh, n_nodes=4)
        plane.ops(np.asarray([0], np.int32), np.asarray([0], np.int32),
                  np.asarray([1], np.int32),
                  np.asarray([[41]], np.int32))
        plane.evict(np.asarray([0], np.int32),
                    np.asarray([0], np.int32))
        plane.replicate([0])
        res = plane.ops(np.asarray([1, 2, 3], np.int32),
                        np.zeros(3, np.int32), np.zeros(3, np.int32))
        assert res.version.tolist() == [1, 1, 1]
        assert res.data[:, 0].tolist() == [41, 41, 41]
        assert int(res.telemetry["replica_served"].sum()) == 3
        plane.check()
        res = plane.ops(np.asarray([2], np.int32),
                        np.asarray([0], np.int32),
                        np.asarray([1], np.int32),
                        np.asarray([[42]], np.int32))
        assert not bool(np.asarray(plane.state["replica_ok"])[0])
        plane.evict(np.asarray([2], np.int32),
                    np.asarray([0], np.int32))
        res = plane.ops(np.asarray([1, 3], np.int32),
                        np.zeros(2, np.int32), np.zeros(2, np.int32))
        assert res.version.tolist() == [2, 2]
        assert res.data[:, 0].tolist() == [42, 42]
        plane.check()

        # replicated lines survive a migration: the replica plane keys
        # by LINE id, so re-homing the line keeps the image serving
        plane.rehome([0], [3])
        res = plane.ops(np.asarray([1], np.int32),
                        np.zeros(1, np.int32), np.zeros(1, np.int32))
        assert res.version.tolist() == [2]
        assert int(res.telemetry["replica_served"].sum()) == 1
        plane.check()
        print("CONGESTION_PARITY_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "CONGESTION_PARITY_OK" in out.stdout, out.stderr[-3000:]
