"""End-to-end driver (the paper's kind = serving infrastructure):
multi-replica LLM serving over the SELCC-coherent disaggregated KV pool.

Two serving replicas share one disaggregated KV-page pool.  A batch of
requests shares a system-prompt prefix: replica 0 prefills it ONCE into
shared pages; both replicas then decode their own requests, reading the
shared prefix pages THROUGH their SELCC caches (miss -> combined
latch+fetch, then hits).  A prefix update (new system prompt version)
invalidates cached copies on every replica — the MSI walk of Fig. 2 on
real model state.

    PYTHONPATH=src python examples/serve_paged.py
"""

import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.dsm.kvpool import KVPoolConfig, SELCCKVPool
from repro.models import lm
from repro.models.lm import NO_PARALLEL as CTX

ARCH = "llava-next-mistral-7b"       # dense backbone, GQA
PAGE = 16
PREFIX_TOKENS = 64
GEN_TOKENS = 24
BATCH_PER_REPLICA = 4


def main():
    cfg = get_smoke_config(ARCH).replace(n_patches=0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pool_cfg = KVPoolConfig(
        n_pages=512, page_size=PAGE, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, n_replicas=2, cache_slots=128)
    # one pool per layer (stacked): here a single pool with layer-major
    # page allocation keeps the demo readable
    pools = [SELCCKVPool(pool_cfg) for _ in range(cfg.n_layers)]

    rng = np.random.default_rng(0)
    prefix = jnp.asarray(rng.integers(0, cfg.vocab, (1, PREFIX_TOKENS)),
                         jnp.int32)

    # ---- replica 0 prefills the shared prefix ONCE -----------------------
    t0 = time.time()
    _, cache = lm.prefill(params, {"tokens": prefix, "labels": prefix},
                          cfg, CTX)
    prefix_pages = []
    for li in range(cfg.n_layers):
        pages = pools[li].allocate(PREFIX_TOKENS // PAGE)
        for pi, page in enumerate(pages):
            ks = cache["k"][li, 0, pi * PAGE:(pi + 1) * PAGE]
            vs = cache["v"][li, 0, pi * PAGE:(pi + 1) * PAGE]
            for t in range(PAGE):
                pools[li].append(np.array([page]), np.array([t]),
                                 ks[t][None], vs[t][None])
        prefix_pages.append(pages)
    print(f"[prefill] shared prefix ({PREFIX_TOKENS} tokens) -> "
          f"{len(prefix_pages[0])} pages/layer in {time.time()-t0:.1f}s")

    # ---- both replicas decode, reading the prefix through SELCC ----------
    hits = misses = 0
    for replica in (0, 1):
        for li in (0, 1):            # probe two layers for the demo stats
            for _ in range(BATCH_PER_REPLICA):
                _, _, h = pools[li].read(replica,
                                         prefix_pages[li].astype(np.int32))
                hits += int(h.sum())
                misses += int((~h.astype(bool)).sum())
    print(f"[decode-prep] prefix page reads: hits={hits} misses={misses} "
          f"(each replica misses once per page, then hits)")

    # ---- decode loop with per-replica private tail pages ------------------
    for replica in (0, 1):
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (BATCH_PER_REPLICA, 1)), jnp.int32)
        dc = lm.init_decode_cache(cfg, BATCH_PER_REPLICA,
                                  PREFIX_TOKENS + GEN_TOKENS)
        # seed the decode cache with the shared prefix KV
        for li in range(cfg.n_layers):
            kb = jnp.broadcast_to(cache["k"][li, 0][None],
                                  (BATCH_PER_REPLICA, PREFIX_TOKENS,
                                   cfg.n_kv_heads, cfg.hd))
            vb = jnp.broadcast_to(cache["v"][li, 0][None], kb.shape)
            dc["k"] = dc["k"].at[li, :, :PREFIX_TOKENS].set(kb)
            dc["v"] = dc["v"].at[li, :, :PREFIX_TOKENS].set(vb)
        dc["pos"] = jnp.full((BATCH_PER_REPLICA,), PREFIX_TOKENS,
                             jnp.int32)
        t0 = time.time()
        step = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg, CTX))
        for _ in range(GEN_TOKENS):
            logits, dc = step(params, dc, toks)
            toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        dt = time.time() - t0
        print(f"[replica {replica}] generated {GEN_TOKENS} tokens x "
              f"{BATCH_PER_REPLICA} seqs in {dt:.1f}s "
              f"({BATCH_PER_REPLICA*GEN_TOKENS/dt:.0f} tok/s)")

    # ---- prefix UPDATE: writer invalidates every cached copy --------------
    page0 = int(prefix_pages[0][0])
    pools[0].append(np.array([page0]), np.array([0]),
                    jnp.zeros((1, cfg.n_kv_heads, cfg.hd)),
                    jnp.zeros((1, cfg.n_kv_heads, cfg.hd)))
    _, _, h0 = pools[0].read(0, np.array([page0], np.int32))
    _, _, h1 = pools[0].read(1, np.array([page0], np.int32))
    print(f"[coherence] after prefix update: replica hits = "
          f"{bool(h0[0])}/{bool(h1[0])} (stale copies invalidated)")
    _, _, h0b = pools[0].read(0, np.array([page0], np.int32))
    print(f"[coherence] next read hits again: {bool(h0b[0])}")


if __name__ == "__main__":
    main()
