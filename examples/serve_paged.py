"""End-to-end driver (the paper's kind = serving infrastructure):
multi-replica LLM serving over the SELCC-coherent disaggregated KV pool
— now driven by the CONTINUOUS-BATCHING engine (``repro.serve``).

Two serving replicas share one disaggregated KV-page pool on the
rounds-plane coherence engine.  A batch of requests shares a
system-prompt prefix: it is prefilled ONCE into shared pages through
coherent plane writes; both replicas' requests then stream through
``serve.ServeLoop`` — one fused ``run_rmw`` append per tick lands every
slot's new KV in the pool, with each slot's private tail pages keeping
the per-call atomicity contract.  The decode compute itself runs
through the SAME jitted ``lm.decode_step`` the pre-engine script used,
wrapped as a serve-model adapter — so the engine's outputs are asserted
TOKEN-FOR-TOKEN IDENTICAL to the hand-rolled reference loop kept below.
A prefix update at the end invalidates cached copies on every replica —
the MSI walk of Fig. 2 on real model state.

    PYTHONPATH=src python examples/serve_paged.py
"""

import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.dsm.kvpool import KVPoolConfig, SELCCKVPool
from repro.models import lm
from repro.models.lm import NO_PARALLEL as CTX
from repro.serve import DecodeOut, ServeLoop, write_pages

ARCH = "llava-next-mistral-7b"       # dense backbone, GQA
PAGE = 16
PREFIX_TOKENS = 64
GEN_TOKENS = 24
BATCH_PER_REPLICA = 4
N_REPLICAS = 2


def seeded_decode_cache(cfg, cache):
    """A decode cache holding the shared prefix KV at pos=PREFIX_TOKENS
    — both the reference loop and the engine adapter start from this
    exact state, per replica."""
    dc = lm.init_decode_cache(cfg, BATCH_PER_REPLICA,
                              PREFIX_TOKENS + GEN_TOKENS)
    for li in range(cfg.n_layers):
        kb = jnp.broadcast_to(cache["k"][li, 0][None],
                              (BATCH_PER_REPLICA, PREFIX_TOKENS,
                               cfg.n_kv_heads, cfg.hd))
        vb = jnp.broadcast_to(cache["v"][li, 0][None], kb.shape)
        dc["k"] = dc["k"].at[li, :, :PREFIX_TOKENS].set(kb)
        dc["v"] = dc["v"].at[li, :, :PREFIX_TOKENS].set(vb)
    dc["pos"] = jnp.full((BATCH_PER_REPLICA,), PREFIX_TOKENS, jnp.int32)
    return dc


class LMAdapter:
    """Serve-model surface around the SAME jitted ``lm.decode_step``
    callable the reference loop uses: per engine tick it steps each
    replica's gang with identical inputs, hands the engine the layer-0
    KV of the consumed tokens (for the fused coherent append into pool
    pages), and emits the argmax next tokens.  ``q=None`` opts out of
    the engine's fused attend — this model runs its own attention
    inside ``decode_step``."""

    def __init__(self, params, cfg, step, cache):
        self.params, self.cfg, self.step = params, cfg, step
        self.n_kv_heads = cfg.n_kv_heads
        self.head_dim = cfg.hd
        self.n_q_heads = cfg.n_heads
        self._dc = [seeded_decode_cache(cfg, cache)
                    for _ in range(N_REPLICAS)]

    def prefill_kv(self, req, tokens, positions):
        raise NotImplementedError("single-token prompts never prefill")

    def decode(self, views):
        outs = {}
        for rep in range(N_REPLICAS):
            gang = [w for w in views if w.sid % N_REPLICAS == rep]
            if not gang:
                continue
            gang.sort(key=lambda w: w.sid)
            assert len(gang) == BATCH_PER_REPLICA, \
                "this demo admits whole replica gangs up front"
            dc = self._dc[rep]
            toks = jnp.asarray([[w.pending] for w in gang], jnp.int32)
            pos = int(np.asarray(dc["pos"])[0])
            logits, dc = self.step(self.params, dc, toks)
            self._dc[rep] = dc
            nxt = np.asarray(jnp.argmax(logits, -1))
            k = np.asarray(dc["k"][0, :, pos], np.float32)
            v = np.asarray(dc["v"][0, :, pos], np.float32)
            for b, w in enumerate(gang):
                outs[w.sid] = DecodeOut(k=k[b], v=v[b],
                                        token=int(nxt[b]), q=None)
        return [outs[w.sid] for w in views]


def main():
    cfg = get_smoke_config(ARCH).replace(n_patches=0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg, CTX))

    rng = np.random.default_rng(0)
    prefix = jnp.asarray(rng.integers(0, cfg.vocab, (1, PREFIX_TOKENS)),
                         jnp.int32)

    # ---- the shared prefix, prefilled once -------------------------------
    t0 = time.time()
    _, cache = lm.prefill(params, {"tokens": prefix, "labels": prefix},
                          cfg, CTX)
    print(f"[prefill] shared prefix ({PREFIX_TOKENS} tokens) computed "
          f"in {time.time()-t0:.1f}s")

    # per-replica initial tokens, drawn exactly as the reference did
    toks0 = [jnp.asarray(rng.integers(0, cfg.vocab,
                                      (BATCH_PER_REPLICA, 1)), jnp.int32)
             for _ in range(N_REPLICAS)]

    # ---- REFERENCE: the pre-engine hand-rolled decode loop ---------------
    ref_tokens = {}                  # (replica, seq) -> [GEN_TOKENS]
    for replica in range(N_REPLICAS):
        toks = toks0[replica]
        dc = seeded_decode_cache(cfg, cache)
        t0 = time.time()
        for _ in range(GEN_TOKENS):
            logits, dc = step(params, dc, toks)
            toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            for b in range(BATCH_PER_REPLICA):
                ref_tokens.setdefault((replica, b), []).append(
                    int(toks[b, 0]))
        dt = time.time() - t0
        print(f"[reference r{replica}] {GEN_TOKENS} tokens x "
              f"{BATCH_PER_REPLICA} seqs in {dt:.1f}s "
              f"({BATCH_PER_REPLICA*GEN_TOKENS/dt:.0f} tok/s)")

    # ---- ENGINE: the same workload through serve.ServeLoop ---------------
    pool_cfg = KVPoolConfig(
        n_pages=64, page_size=PAGE, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, n_replicas=N_REPLICAS, cache_slots=32)
    pool = SELCCKVPool(pool_cfg)
    pool.open_rounds_plane()

    # shared prefix -> shared pool pages via coherent plane writes
    prefix_pages = pool.allocate(PREFIX_TOKENS // PAGE)
    kp = np.asarray(cache["k"][0, 0], np.float32).reshape(
        -1, PAGE, cfg.n_kv_heads, cfg.hd)
    vp = np.asarray(cache["v"][0, 0], np.float32).reshape(
        -1, PAGE, cfg.n_kv_heads, cfg.hd)
    write_pages(pool, prefix_pages, kp, vp)

    adapter = LMAdapter(params, cfg, step, cache)
    loop = ServeLoop(pool, adapter, n_slots=N_REPLICAS * BATCH_PER_REPLICA,
                     max_pages=(PREFIX_TOKENS + 1 + GEN_TOKENS - 1
                                + PAGE - 1) // PAGE,
                     prefill_chunk=1,
                     queue_capacity=N_REPLICAS * BATCH_PER_REPLICA)
    reqs = {}
    for b in range(BATCH_PER_REPLICA):       # slot 2b+r -> replica r
        for replica in range(N_REPLICAS):
            reqs[(replica, b)] = loop.submit(
                [int(toks0[replica][b, 0])], GEN_TOKENS,
                shared_pages=tuple(int(p) for p in prefix_pages),
                shared_len=PREFIX_TOKENS)
    t0 = time.time()
    loop.start()
    assert loop.drain(timeout=600), "engine failed to drain"
    loop.stop()
    dt = time.time() - t0
    st = loop.stats()
    total = N_REPLICAS * BATCH_PER_REPLICA * GEN_TOKENS
    print(f"[engine] {total} tokens across {st.completed} requests in "
          f"{dt:.1f}s ({total/dt:.0f} tok/s), {st.tick} ticks, "
          f"{st.appended_tokens} KV rows through "
          f"{st.rounds_total} coherence rounds, "
          f"pool pages in use after evict: {st.pages_in_use}")

    # ---- the engine must reproduce the reference TOKEN FOR TOKEN ---------
    for key, ref in sorted(ref_tokens.items()):
        got = reqs[key].generated
        assert got == ref, f"replica/seq {key}: {got} != {ref}"
    print(f"[check] engine outputs identical to the hand-rolled "
          f"reference for all {len(ref_tokens)} sequences")
    assert st.pages_in_use == len(prefix_pages), "leaked slot pages"

    # ---- prefix UPDATE: writer invalidates every cached copy --------------
    page0 = np.asarray([prefix_pages[0]], np.int32)
    _, _, h0 = pool.read(0, page0)
    _, _, h1 = pool.read(1, page0)
    _, _, h0b = pool.read(0, page0)
    print(f"[coherence] prefix page reads: first={bool(h0[0])}/"
          f"{bool(h1[0])} then hit={bool(h0b[0])}")
    zeros = np.zeros((1, cfg.n_kv_heads, cfg.hd), np.float32)
    pool.append(page0, np.array([0]), zeros, zeros, replica=0)
    _, _, h0c = pool.read(0, page0)
    _, _, h1c = pool.read(1, page0)
    print(f"[coherence] after prefix update by r0: reader re-reads "
          f"hit={bool(h0c[0])}/{bool(h1c[0])} (r1's stale copy was "
          f"invalidated)")


if __name__ == "__main__":
    main()
