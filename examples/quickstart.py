"""Quickstart: the SELCC v2 abstraction layer in ~70 lines.

Typed GAddrs, scope-guarded latches with a real data plane
(``h.value`` / ``h.store``), lazy release + invalidation in action, the
pluggable backend registry, and a B-link tree over the same API
(paper Table 1 + Sec. 8.1).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
sys.path.insert(0, "src")

from repro.apps import BLinkTree
from repro.core import (ClusterConfig, SELCCConfig, SELCCLayer,
                        available_protocols)


def main():
    print(f"registered protocol backends: {available_protocols()}")
    layer = SELCCLayer(ClusterConfig(n_compute=2, n_memory=2,
                                     threads_per_node=4,
                                     selcc=SELCCConfig(cache_capacity=256)))
    node0, node1 = layer.nodes
    gaddr = layer.allocate()
    print(f"allocated GCL at gaddr={gaddr!r} "
          f"(typed; packs to 0x{gaddr.pack():x})")

    def demo():
        # node 0 writes a real payload under an exclusive scope guard
        h = yield from node0.xlocked(gaddr)
        yield from h.store({"greeting": "hello, disaggregated world"})
        yield from h.release()
        print(f"  node0 stored {layer.heap.load(gaddr)} at v{h.version}; "
              f"latch is released LAZILY (still held globally)")
        # node 1 reads: its acquisition invalidates node 0's copy
        h1 = yield from node1.slocked(gaddr)
        print(f"  node1 read  {h1.value!r} at v{h1.version} (coherent)")
        yield from h1.release()
        # node 1 reads again: pure LOCAL cache hit — zero RDMA
        before = layer.fabric.stats.total_rdma()
        h1 = yield from node1.slocked(gaddr)
        yield from h1.release()
        after = layer.fabric.stats.total_rdma()
        print(f"  node1 re-read: cache hit, RDMA ops used = "
              f"{after - before}")
        # global timestamps via the Atomic API
        ts1 = yield from node0.atomic_faa(layer.allocate(), 1)
        print(f"  Atomic FAA timestamp = {ts1}")

    p = layer.env.process(demo())
    layer.env.run_until_complete([p])

    # ---- a real data structure over the same five calls ------------------
    tree = BLinkTree(layer, node0, fanout=16)

    def tree_demo():
        for i in range(200):
            yield from tree.insert(i, i * i)
        v = yield from tree.lookup(137)
        rng = yield from tree.range_scan(50, 5)
        print(f"  btree over SELCC: lookup(137)={v}, scan(50,5)={rng}")

    p = layer.env.process(tree_demo())
    layer.env.run_until_complete([p])
    layer.assert_released()           # every scope guard closed
    cs = layer.cache_stats()
    print(f"cache: hits={cs['hits']} misses={cs['misses']} "
          f"hit_rate={cs['hits'] / (cs['hits'] + cs['misses']):.1%}")

    # ---- same app, different backend: resolved via the registry ----------
    rpc_layer = SELCCLayer(ClusterConfig(n_compute=2, n_memory=2,
                                         threads_per_node=4,
                                         protocol="rpc"))
    rpc_tree = BLinkTree(rpc_layer, rpc_layer.nodes[0], fanout=16)

    def rpc_demo():
        for i in range(50):
            yield from rpc_tree.insert(i, -i)
        v = yield from rpc_tree.lookup(42)
        print(f"  SAME btree code over the 'rpc' strawman: lookup(42)={v}")

    p = rpc_layer.env.process(rpc_demo())
    rpc_layer.env.run_until_complete([p])
    rpc_layer.assert_released()


if __name__ == "__main__":
    main()
