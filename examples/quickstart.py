"""Quickstart: the SELCC abstraction layer in 60 lines.

Allocates Global Cache Lines, takes shared/exclusive SELCC latches from
two compute nodes, shows lazy release + invalidation in action, and runs
a B-link tree over the same API (paper Table 1 + Sec. 8.1).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
sys.path.insert(0, "src")

from repro.apps.btree import BLinkTree
from repro.core import ClusterConfig, SELCCConfig, SELCCLayer


def main():
    layer = SELCCLayer(ClusterConfig(n_compute=2, n_memory=2,
                                     threads_per_node=4,
                                     selcc=SELCCConfig(cache_capacity=256)))
    node0, node1 = layer.nodes
    gaddr = layer.allocate()
    print(f"allocated GCL at gaddr={gaddr}")

    def demo():
        # node 0 writes under the exclusive SELCC latch
        h = yield from node0.xlock(gaddr)
        yield from node0.write(h)
        yield from node0.xunlock(h)
        print(f"  node0 wrote v{h.version}; latch is released LAZILY "
              f"(still held globally)")
        # node 1 reads: its acquisition invalidates node 0's copy
        h1 = yield from node1.slock(gaddr)
        print(f"  node1 read  v{h1.version} (coherent)")
        yield from node1.sunlock(h1)
        # node 1 reads again: pure LOCAL cache hit — zero RDMA
        before = layer.fabric.stats.total_rdma()
        h1 = yield from node1.slock(gaddr)
        yield from node1.sunlock(h1)
        after = layer.fabric.stats.total_rdma()
        print(f"  node1 re-read: cache hit, RDMA ops used = "
              f"{after - before}")
        # global timestamps via the Atomic API
        ts1 = yield from node0.atomic_faa(layer.allocate(), 1)
        print(f"  Atomic FAA timestamp = {ts1}")

    p = layer.env.process(demo())
    layer.env.run_until_complete([p])

    # ---- a real data structure over the same five calls ------------------
    tree = BLinkTree(layer, node0, fanout=16)

    def tree_demo():
        for i in range(200):
            yield from tree.insert(i, i * i)
        v = yield from tree.lookup(137)
        rng = yield from tree.range_scan(50, 5)
        print(f"  btree over SELCC: lookup(137)={v}, scan(50,5)={rng}")

    p = layer.env.process(tree_demo())
    layer.env.run_until_complete([p])
    cs = layer.cache_stats()
    print(f"cache: hits={cs['hits']} misses={cs['misses']} "
          f"hit_rate={cs['hits'] / (cs['hits'] + cs['misses']):.1%}")


if __name__ == "__main__":
    main()
