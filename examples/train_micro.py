"""Train a ~100M-parameter dense LM for a few hundred steps on CPU,
exercising the full production path: grad accumulation, checkpointing,
resume, straggler watchdog.

    PYTHONPATH=src python examples/train_micro.py [--steps 300]

(~100M params: d=768, L=12, vocab 32k — qwen3-family block.  Use
--tiny for a 2-minute variant.)
"""

import sys
sys.path.insert(0, "src")

import argparse

from repro.configs import get_smoke_config
from repro.launch import train as train_mod
from repro.models.config import LMConfig

MODEL_100M = LMConfig(
    name="micro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32_000, qk_norm=True,
    tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/selcc_train_micro")
    args = ap.parse_args()

    # register the 100M config under a private name
    import repro.configs as configs
    if args.tiny:
        cfg = get_smoke_config("qwen3-1.7b")
    else:
        cfg = MODEL_100M
        print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.0f}M params")

    class _Mod:
        CONFIG = cfg
        SMOKE_CONFIG = cfg
    sys.modules["repro.configs.micro_100m"] = _Mod
    configs.CANON["micro-100m"] = "micro_100m"

    train_mod.main([
        "--arch", "micro-100m", "--smoke",
        "--steps", str(args.steps),
        "--batch", "4", "--seq", "256", "--lr", "1e-3",
        "--ckpt", args.ckpt, "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
