"""Elastic restart walkthrough: a data-parallel row dies mid-training and
the job continues on the survivors.

Single-process demo on a 1x1 mesh (the multi-device version runs in
tests/test_elastic_e2e.py under 8 virtual devices): shows the operator
flow — heartbeats, failure verdict, elastic plan, checkpoint restore with
new shardings, batch rescale.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.runtime import FailureDetector, StragglerWatchdog, \
    plan_elastic_mesh
from repro.train import TrainConfig, build_train_step, init_train_state


def main():
    cfg = get_smoke_config("qwen3-1.7b")
    tcfg = TrainConfig(remat=False, opt=AdamWConfig(
        lr=1e-3, warmup_steps=2, total_steps=12))
    mesh = make_local_mesh()
    step_fn, _, _ = build_train_step(cfg, mesh, tcfg, global_batch=8)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=8, seq_len=64))
    mgr = CheckpointManager("/tmp/elastic_demo", keep=2)
    fd = FailureDetector([f"h{i}" for i in range(4)], suspect_after=5,
                         dead_after=10)
    dog = StragglerWatchdog()

    print("[phase 1] healthy training on 4 data rows (logical)")
    for s in range(4):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, m = jit_step(state, batch)
        for h in ("h0", "h1", "h2", "h3"):
            fd.beat(h)
        dog.observe(0.1, slowest_host="h2")
        print(f"  step {s}: loss {float(m['loss']):.4f}")
    mgr.save(state, 3)
    mgr.wait()

    print("[phase 2] h1 stops heartbeating...")
    fd.last_beat["h1"] -= 100
    alive, suspect, dead = fd.sweep()
    print(f"  detector verdict: dead={dead}")
    plan = plan_elastic_mesh(4, 2, dead_hosts=dead,
                             host_of_device=lambda d, m: f"h{d}")
    print(f"  elastic plan: keep rows {plan.data_rows}, "
          f"batch scale {plan.batch_scale:.2f}")

    print("[phase 3] restore + continue with rescaled batch")
    new_batch = max(2, int(8 * plan.batch_scale) // 2 * 2)
    step_fn2, _, _ = build_train_step(cfg, mesh, tcfg,
                                      global_batch=new_batch)
    state2, step = mgr.restore(jax.eval_shape(lambda: state))
    jit2 = jax.jit(step_fn2, donate_argnums=(0,))
    data2 = SyntheticLM(DataConfig(vocab=cfg.vocab, batch=new_batch,
                                   seq_len=64))
    for s in range(step + 1, step + 4):
        batch = {k: jnp.asarray(v) for k, v in data2.batch_at(s).items()}
        state2, m = jit2(state2, batch)
        print(f"  step {s}: loss {float(m['loss']):.4f} "
              f"(batch {new_batch})")
    print("[done] training continued across the failure")


if __name__ == "__main__":
    main()
